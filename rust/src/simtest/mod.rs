//! Deterministic simulation-test harness (FoundationDB-style) for the
//! serving stack.
//!
//! A single seed expands into a complete scripted world — engine
//! configuration (pool sizes chosen to create KV pressure, stream
//! capacities chosen to create credit starvation, backpressure policy,
//! idle timeout), a mixed-tenant/priority workload with shared prompt
//! prefixes, and a client script per request (eager readers, slow
//! readers, readers that stall forever, readers that disconnect,
//! cancels, admin bulk-cancels, stop sequences, tight token budgets).
//! The harness drives the *entire* stack — router → policy → scheduler
//! → batcher → kvcache/prefixcache → [`crate::core::EngineCore`] → api
//! streams — under a virtual clock ([`SimClock`]; the sim advances
//! [`crate::simengine::SIM_STEP`] per step), applying the scripted
//! client actions in a seed-derived (deliberately reordered) order each
//! step.
//!
//! The harness is generic over the engine's compute [`Backend`]:
//! [`run_scenario`] drives the hash-model [`SimEngine`], and
//! [`run_scenario_on`] drives the same scripted world through any
//! other backend — `tests/differential_backends.rs` uses it to run
//! `EngineCore<StubBackend>` in lockstep and assert byte-identical
//! reports, proving the orchestration core treats backends uniformly.
//!
//! After every simulated step five global oracles run:
//!
//! 1. **KV refcount conservation** — every block's refcount equals the
//!    owners visible in the audit (sequence block tables + prefix-tree
//!    references); a block is on the free list exactly when its
//!    refcount is zero; the free list holds no duplicates. Any leak or
//!    double-free — including one injected through the `#[cfg(test)]`
//!    fault hook — trips this oracle on the very step it happens.
//! 2. **Stream-credit bounds** — no live request ever buffers more
//!    than its configured stream capacity, and (checked at the end) a
//!    retained client drains *exactly* the token sequence the engine
//!    emitted: nothing lost or reordered across pause/resume.
//! 3. **Priority monotonicity** — every preemption event carries the
//!    candidate pool it was chosen from; the victim's priority must not
//!    exceed any other candidate's, and an admission-relief victim must
//!    be strictly below its waiter.
//! 4. **Usage conservation** — per finished request,
//!    `cached + prefill == prompt_tokens` (or both zero when never
//!    admitted) and `generated` equals the tokens actually emitted;
//!    globally, the per-request usages sum to the engine's token
//!    counter.
//! 5. **Span conservation** — every request timeline the engine's
//!    observability layer retains ([`crate::obs::RequestSpan`], live
//!    and finished) is a legal, monotone state machine (submitted →
//!    admitted → first token → decode ⇄ paused → finished) whose
//!    finished phases partition its total exactly, and the span
//!    counters agree with the engine's admission/finish accounting.
//!
//! A violation reports the seed, the step, a replay command, and the
//! newest entries of the engine's always-on flight recorder
//! ([`crate::obs::FlightRecorder`]) — the failing seed ships its own
//! black box. The same seed reproduces the run byte-identically (equal
//! [`ScenarioReport::fingerprint`]); the flight dump is deterministic
//! too, because it is stamped from the virtual clock.
//!
//! [`run_crash_recovery`] additionally scripts a mid-run engine crash:
//! the core is dropped at a seed-derived step, a fresh core is built,
//! and the unfinished requests are resubmitted from the server-side
//! [`RequestRegistry`] — the refcount oracle holds on every step of
//! both lives and everything resubmitted still finishes.
//!
//! See `docs/ARCHITECTURE.md` § "Testing & determinism" for the
//! workflow (seed matrix, replay, adding scenarios).

use std::collections::HashMap;
use std::fmt;

use crate::api::{FinishReason, GenEvent, GenRequest, InferenceEngine, SubmissionHandle, Usage};
use crate::config::{BackpressurePolicy, EngineConfig, FleetConfig, RoutePolicy};
use crate::core::{Backend, EngineCore, TraceEvent};
use crate::fleet::Fleet;
use crate::kvcache::SeqId;
use crate::router::RequestRegistry;
use crate::shard::ShardedBackend;
use crate::simengine::{SimBackend, SimEngine, SimSpec};
use crate::util::rng::{splitmix64, Rng};

pub use crate::core::check_kv_conservation;
pub use crate::simengine::SIM_STEP;
/// The virtual clock the sim path runs on (re-export; see
/// [`crate::util::clock::Clock`]).
pub use crate::util::clock::Clock as SimClock;

/// Hard cap on harness steps: hitting it is itself a liveness
/// violation (the stack wedged under some client behavior).
const MAX_STEPS: usize = 20_000;

/// Flight-recorder lines appended to a violation report.
const FLIGHT_DUMP_LINES: usize = 40;

// ---------------------------------------------------------------------
// Scenario model
// ---------------------------------------------------------------------

/// How a scripted client consumes its event stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reader {
    /// Drains everything every step.
    Eager,
    /// Drains up to `burst` events every `period` steps (slow client).
    EveryK { period: usize, burst: usize },
    /// Reads until it has seen `tokens` tokens, then never reads again
    /// (until the scenario's cleanup phase) — the stall that exercises
    /// pause/park/idle-timeout paths.
    StallAfter { tokens: usize },
    /// Reads until it has seen `tokens` tokens, then drops its handle
    /// (client disconnect mid-generation).
    DisconnectAfter { tokens: usize },
}

/// One scripted request: what is submitted, and how its client behaves.
#[derive(Debug, Clone)]
pub struct ClientScript {
    pub arrive_step: usize,
    pub prompt: String,
    pub tenant: String,
    pub priority: i32,
    pub stop: Vec<String>,
    pub max_new_tokens: usize,
    pub reader: Reader,
    /// Harness step at which the client cancels its own request.
    pub cancel_at: Option<usize>,
}

impl ClientScript {
    /// The typed request this script submits.
    fn request(&self) -> GenRequest {
        let mut req = GenRequest::text(&self.prompt)
            .tenant(&self.tenant)
            .priority(self.priority)
            .max_new_tokens(self.max_new_tokens);
        if !self.stop.is_empty() {
            req = req.stop(self.stop.clone());
        }
        req
    }
}

/// A fully expanded scenario: everything [`run_scenario`] needs,
/// derived deterministically from one seed.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub seed: u64,
    pub cfg: EngineConfig,
    pub clients: Vec<ClientScript>,
    /// Optional admin action: at `(step)`, bulk-cancel every in-flight
    /// request of `tenant` (the server's `cancel_tenant` verb, driven
    /// through the same engine cancel path).
    pub admin_cancel: Option<(usize, String)>,
    /// Step at which every reader turns eager so the scenario drains
    /// and terminates (stalls are forever until then).
    pub horizon: usize,
}

/// Expand a seed into a scenario. Every knob — pool pressure, stream
/// capacity, policy, tenants, priorities, shared prefixes, reader
/// behavior, cancels — comes from the seeded RNG and nothing else.
pub fn generate_scenario(seed: u64) -> Scenario {
    let mut rng = Rng::seed_from_u64(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x51D_7E57);
    let kv_block_tokens = if rng.next_u64() % 2 == 0 { 4 } else { 8 };
    let cfg = EngineConfig {
        kv_block_tokens,
        // Small pools on purpose: KV-pressure spikes are the fault
        // plane that exercises eviction and preemption.
        kv_total_blocks: rng.gen_range(10, 40),
        max_new_tokens: rng.gen_range(4, 16),
        max_running: rng.gen_range(1, 4),
        decode_buckets: vec![1, 2, 4],
        prefix_cache: rng.next_u64() % 4 != 0,
        // Tiny stream buffers: credit starvation is the point.
        stream_capacity: rng.gen_range(1, 4),
        backpressure: if rng.next_u64() % 10 < 7 {
            BackpressurePolicy::PauseDecode
        } else {
            BackpressurePolicy::DropSlow
        },
        stream_idle_timeout_ms: if rng.next_u64() % 3 == 0 {
            rng.gen_range(5, 40) as u64
        } else {
            0
        },
        seed,
        ..EngineConfig::default()
    };

    let prefixes = ["sys0: shared preamble ", "sys1: other preamble! ", "u: "];
    let tenants = ["acme", "globex", "initech"];
    let n = rng.gen_range(6, 16);
    let mut clients = Vec::with_capacity(n);
    for i in 0..n {
        let prefix = prefixes[rng.gen_range(0, prefixes.len() - 1)];
        let prompt = format!("{prefix}{i:02}");
        let stop = if rng.next_u64() % 5 == 0 {
            // A single printable byte; the hash model emits those often
            // enough that some scenarios hit it.
            vec![String::from_utf8(vec![rng.gen_range(97, 122) as u8]).unwrap()]
        } else {
            Vec::new()
        };
        let reader = match rng.next_u64() % 10 {
            0..=3 => Reader::Eager,
            4..=6 => Reader::EveryK {
                period: rng.gen_range(1, 4),
                burst: rng.gen_range(1, 3),
            },
            7..=8 => Reader::StallAfter {
                tokens: rng.gen_range(1, 4),
            },
            _ => Reader::DisconnectAfter {
                tokens: rng.gen_range(1, 4),
            },
        };
        let arrive_step = rng.gen_range(0, 30);
        let cancel_at = if rng.next_u64() % 7 == 0 {
            Some(arrive_step + rng.gen_range(2, 25))
        } else {
            None
        };
        clients.push(ClientScript {
            arrive_step,
            prompt,
            tenant: tenants[rng.gen_range(0, tenants.len() - 1)].to_string(),
            priority: rng.gen_range(0, 5) as i32 - 2,
            stop,
            max_new_tokens: rng.gen_range(2, 12),
            reader,
            cancel_at,
        });
    }
    let admin_cancel = if rng.next_u64() % 4 == 0 {
        Some((
            rng.gen_range(10, 50),
            tenants[rng.gen_range(0, tenants.len() - 1)].to_string(),
        ))
    } else {
        None
    };
    Scenario {
        seed,
        cfg,
        clients,
        admin_cancel,
        horizon: 200,
    }
}

// ---------------------------------------------------------------------
// Violations and reports
// ---------------------------------------------------------------------

/// An oracle failure: what broke, where, and how to replay it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub seed: u64,
    pub step: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "simtest oracle violation at step {} (seed {}): {}",
            self.step, self.seed, self.message
        )?;
        write!(
            f,
            "  replay: cargo run --example simtest -- --seed {}",
            self.seed
        )
    }
}

impl std::error::Error for Violation {}

/// Aggregate outcome of one scenario run. Two runs of the same seed
/// must produce equal reports — `fingerprint` folds the full trace and
/// every drained token, so equality means byte-identical behavior.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioReport {
    pub seed: u64,
    pub steps: usize,
    pub requests: usize,
    pub finished: u64,
    pub preemptions: u64,
    pub pauses: u64,
    pub resumes: u64,
    pub expired: u64,
    pub disconnects: u64,
    pub cancellations: u64,
    pub tokens_generated: u64,
    pub fingerprint: u64,
}

/// Everything a [`ScenarioReport`] says about *behavior*, with the one
/// field that measures *pacing* (`steps`) projected out. Chunked decode
/// (`EngineConfig::decode_chunk`) generates several tokens per engine
/// step, so a chunked run legitimately takes fewer scheduler steps —
/// and, under the sim's one-`SIM_STEP`-per-step clock, less virtual
/// time — than an unchunked run of the same world. Every other field,
/// the order-sensitive trace fingerprint above all, must still match
/// exactly; `tests/differential_backends.rs` asserts this over the
/// chunk matrix.
pub fn behavior_key(
    r: &ScenarioReport,
) -> (u64, usize, u64, u64, u64, u64, u64, u64, u64, u64, u64) {
    (
        r.seed,
        r.requests,
        r.finished,
        r.preemptions,
        r.pauses,
        r.resumes,
        r.expired,
        r.disconnects,
        r.cancellations,
        r.tokens_generated,
        r.fingerprint,
    )
}

fn fold(acc: u64, v: u64) -> u64 {
    splitmix64(acc ^ v.wrapping_mul(0xD6E8FEB86659FD93))
}

fn reason_code(r: FinishReason) -> u64 {
    match r {
        FinishReason::Eos => 1,
        FinishReason::MaxTokens => 2,
        FinishReason::Stop => 3,
        FinishReason::Cancelled => 4,
        FinishReason::Preempted => 5,
        FinishReason::Overrun => 6,
        FinishReason::Error => 7,
    }
}

fn fold_event(acc: u64, ev: &TraceEvent) -> u64 {
    match ev {
        TraceEvent::Admitted { id, cached } => fold(fold(fold(acc, 1), *id), *cached as u64),
        TraceEvent::Token { id, token } => fold(fold(fold(acc, 2), *id), *token as u64),
        TraceEvent::Paused { id } => fold(fold(acc, 3), *id),
        TraceEvent::Resumed { id } => fold(fold(acc, 4), *id),
        TraceEvent::Expired { id } => fold(fold(acc, 5), *id),
        TraceEvent::Preempted { id, priority, pool } => {
            let mut a = fold(fold(fold(acc, 6), *id), *priority as u64);
            for (pid, p) in pool {
                a = fold(fold(a, *pid), *p as u64);
            }
            a
        }
        TraceEvent::AdmissionRelief {
            id,
            priority,
            waiter_priority,
        } => fold(
            fold(fold(fold(acc, 7), *id), *priority as u64),
            *waiter_priority as u64,
        ),
        TraceEvent::Finished { id, reason, usage } => fold(
            fold(
                fold(fold(fold(acc, 8), *id), reason_code(*reason)),
                usage.generated_tokens as u64,
            ),
            ((usage.cached_prompt_tokens as u64) << 32) | (usage.prefill_tokens as u64),
        ),
    }
}

/// Fingerprint of a trace slice on its own (no seed folding): the
/// backend-equivalence lockstep test compares these across engines.
pub fn trace_fingerprint(events: &[TraceEvent]) -> u64 {
    events.iter().fold(0x5EEDu64, fold_event)
}

// ---------------------------------------------------------------------
// Oracles
// ---------------------------------------------------------------------

/// Oracle 3 (one event): the preemption victim's priority must be
/// minimal over its candidate pool.
fn check_preemption(id: SeqId, priority: i32, pool: &[(SeqId, i32)]) -> Result<(), String> {
    if let Some(min_other) = pool.iter().filter(|(p, _)| *p != id).map(|(_, p)| *p).min() {
        if priority > min_other {
            return Err(format!(
                "preempted seq {id} (priority {priority}) while a strictly \
                 lower-priority victim (priority {min_other}) existed: {pool:?}"
            ));
        }
    }
    Ok(())
}

/// Oracle 4 (one event): the finished request's usage record must
/// partition its prompt and match the tokens actually emitted.
fn check_usage(usage: &Usage, emitted: usize) -> Result<(), String> {
    let admitted = usage.cached_prompt_tokens + usage.prefill_tokens > 0;
    if admitted && usage.cached_prompt_tokens + usage.prefill_tokens != usage.prompt_tokens {
        return Err(format!(
            "usage does not partition the prompt: cached {} + prefill {} != prompt {}",
            usage.cached_prompt_tokens, usage.prefill_tokens, usage.prompt_tokens
        ));
    }
    if usage.generated_tokens != emitted {
        return Err(format!(
            "usage reports {} generated tokens but {} were emitted",
            usage.generated_tokens, emitted
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// The harness
// ---------------------------------------------------------------------

struct ClientState {
    handle: Option<SubmissionHandle>,
    engine_id: Option<SeqId>,
    submitted: bool,
    dropped: bool,
    drained: Vec<u32>,
    finished: Option<(FinishReason, Usage)>,
}

impl ClientState {
    fn new() -> Self {
        ClientState {
            handle: None,
            engine_id: None,
            submitted: false,
            dropped: false,
            drained: Vec::new(),
            finished: None,
        }
    }

    /// Receive up to `limit` events (`usize::MAX` = drain fully).
    fn receive(&mut self, mut limit: usize) {
        let Some(h) = &self.handle else { return };
        while limit > 0 {
            match h.events.try_recv() {
                Ok(GenEvent::Token(t)) => self.drained.push(t),
                Ok(GenEvent::Finished { reason, usage }) => {
                    self.finished = Some((reason, usage));
                }
                Err(_) => break,
            }
            limit -= 1;
        }
    }

    /// Apply one step of the scripted reader behavior.
    fn read_scripted(&mut self, reader: Reader, step: usize) {
        match reader {
            Reader::Eager => self.receive(usize::MAX),
            Reader::EveryK { period, burst } => {
                if step % period.max(1) == 0 {
                    self.receive(burst);
                }
            }
            Reader::StallAfter { tokens } => {
                let left = tokens.saturating_sub(self.drained.len());
                self.receive(left);
            }
            Reader::DisconnectAfter { tokens } => {
                let left = tokens.saturating_sub(self.drained.len());
                self.receive(left);
                if self.drained.len() >= tokens {
                    self.handle = None; // drop: client vanishes
                    self.dropped = true;
                }
            }
        }
    }
}

/// Run one seeded scenario end to end on the hash-model sim engine with
/// all four oracles armed.
pub fn run_scenario(seed: u64) -> Result<ScenarioReport, Violation> {
    let scenario = generate_scenario(seed);
    let engine = SimEngine::new(scenario.cfg.clone(), SimSpec::default()).map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("engine construction failed: {e}"),
    })?;
    run_with_hook(&scenario, engine, &mut |_, _| {})
}

/// Run one seeded scenario with prefix-shared grouped decode enabled
/// (everything else identical to [`run_scenario`]). Grouping reuses
/// shared-prefix attention compute but must never change an output, so
/// for every seed the report — fingerprint included — must equal
/// [`run_scenario`]'s byte for byte; `tests/differential_backends.rs`
/// asserts this over the seed matrix.
pub fn run_scenario_grouped(seed: u64) -> Result<ScenarioReport, Violation> {
    let scenario = generate_scenario(seed);
    let cfg = EngineConfig {
        grouped_decode: true,
        ..scenario.cfg.clone()
    };
    let engine = SimEngine::new(cfg, SimSpec::default()).map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("grouped engine construction failed: {e}"),
    })?;
    run_with_hook(&scenario, engine, &mut |_, _| {})
}

/// Expand a seed into a *chunk-safe* scenario: a world whose behavior
/// is invariant under `EngineConfig::decode_chunk`, so chunked and
/// unchunked runs must agree on [`behavior_key`] exactly.
///
/// Chunking compresses the harness step axis (several tokens per
/// engine step), so anything a scenario keys off the *step counter*
/// mid-generation would legitimately land at a different point in the
/// token stream and change behavior. This family therefore scripts:
///
/// * all arrivals at step 0 (no mid-run arrival races the compressed
///   step axis),
/// * eager readers only (no `EveryK` pacing, stalls, or disconnects
///   measured in harness steps),
/// * no client `cancel_at` and no admin bulk-cancel (both are
///   step-indexed),
/// * no stream idle timeout (virtual time advances per step, and a
///   chunked run takes fewer steps),
/// * stream capacity 32 — comfortably above the largest chunk, so
///   intra-step token bursts never hit the credit limit.
///
/// Everything *engine-internal* stays adversarial: tight-ish KV pools
/// (preemption and admission queueing still happen — the in-loop
/// `chunk_can_continue` guard is what keeps those identical), stop
/// sequences, mixed priorities and token budgets, prefix sharing.
pub fn generate_chunk_scenario(seed: u64) -> Scenario {
    let mut rng = Rng::seed_from_u64(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xC4C_57A7E);
    let cfg = EngineConfig {
        kv_block_tokens: if rng.next_u64() % 2 == 0 { 4 } else { 8 },
        // Moderate pressure: enough blocks that decode runs, few enough
        // that heavy seeds still preempt.
        kv_total_blocks: rng.gen_range(24, 64),
        max_new_tokens: rng.gen_range(8, 24),
        max_running: rng.gen_range(2, 8),
        decode_buckets: vec![1, 2, 4, 8],
        prefix_cache: rng.next_u64() % 4 != 0,
        stream_capacity: 32,
        backpressure: BackpressurePolicy::PauseDecode,
        stream_idle_timeout_ms: 0,
        seed,
        ..EngineConfig::default()
    };

    let prefixes = ["sys0: shared preamble ", "sys1: other preamble! ", "u: "];
    let tenants = ["acme", "globex", "initech"];
    let n = rng.gen_range(6, 14);
    let mut clients = Vec::with_capacity(n);
    for i in 0..n {
        let prefix = prefixes[rng.gen_range(0, prefixes.len() - 1)];
        let prompt = format!("{prefix}{i:02}");
        let stop = if rng.next_u64() % 5 == 0 {
            vec![String::from_utf8(vec![rng.gen_range(97, 122) as u8]).unwrap()]
        } else {
            Vec::new()
        };
        clients.push(ClientScript {
            arrive_step: 0,
            prompt,
            tenant: tenants[rng.gen_range(0, tenants.len() - 1)].to_string(),
            priority: rng.gen_range(0, 5) as i32 - 2,
            stop,
            max_new_tokens: rng.gen_range(4, 20),
            reader: Reader::Eager,
            cancel_at: None,
        });
    }
    Scenario {
        seed,
        cfg,
        clients,
        admin_cancel: None,
        horizon: 200,
    }
}

/// Run one chunk-safe scenario ([`generate_chunk_scenario`]) with
/// `decode_chunk = chunk`, all five oracles armed. For every seed,
/// [`behavior_key`] of the report must be identical across all chunk
/// values (chunk 1 is the unchunked baseline); only `steps` may differ.
pub fn run_scenario_chunked(seed: u64, chunk: usize) -> Result<ScenarioReport, Violation> {
    let scenario = generate_chunk_scenario(seed);
    let cfg = EngineConfig {
        decode_chunk: chunk,
        ..scenario.cfg.clone()
    };
    let engine = SimEngine::new(cfg, SimSpec::default()).map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("chunked engine construction failed: {e}"),
    })?;
    run_with_hook(&scenario, engine, &mut |_, _| {})
}

/// [`run_scenario_chunked`] with prefix-shared grouped decode on top —
/// the two decode-loop features composed. Must match the plain
/// [`run_scenario_chunked`] behavior key for every (seed, chunk), and
/// transitively the chunk-1 ungrouped baseline.
pub fn run_scenario_chunked_grouped(seed: u64, chunk: usize) -> Result<ScenarioReport, Violation> {
    let scenario = generate_chunk_scenario(seed);
    let cfg = EngineConfig {
        decode_chunk: chunk,
        grouped_decode: true,
        ..scenario.cfg.clone()
    };
    let engine = SimEngine::new(cfg, SimSpec::default()).map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("chunked grouped engine construction failed: {e}"),
    })?;
    run_with_hook(&scenario, engine, &mut |_, _| {})
}

/// One chunk-safe scenario on `EngineCore<ShardedBackend<SimBackend>>`
/// with `decode_chunk = chunk`: sharding must stay invisible under
/// chunked steps, so the behavior key must match the unsharded
/// [`run_scenario_chunked`] for every (seed, chunk, shards).
pub fn run_scenario_chunked_sharded(
    seed: u64,
    chunk: usize,
    shards: usize,
) -> Result<ScenarioReport, Violation> {
    let scenario = generate_chunk_scenario(seed);
    let cfg = EngineConfig {
        decode_chunk: chunk,
        ..scenario.cfg.clone()
    };
    let engine = EngineCore::with_backend(
        ShardedBackend::new(SimBackend::new(SimSpec::default()), shards),
        cfg,
        SimClock::manual(),
    )
    .map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("chunked sharded engine construction failed: {e}"),
    })?;
    run_with_hook(&scenario, engine, &mut |_, _| {})
}

/// One chunk-safe scenario on a single-replica sim [`Fleet`] with
/// `decode_chunk = chunk`: the fleet layer must stay transparent under
/// chunked steps, so the behavior key must match the bare-core
/// [`run_scenario_chunked`] for every (seed, chunk).
pub fn run_scenario_chunked_fleet(
    seed: u64,
    chunk: usize,
    n_replicas: usize,
) -> Result<ScenarioReport, Violation> {
    let scenario = generate_chunk_scenario(seed);
    let cfg = EngineConfig {
        decode_chunk: chunk,
        ..scenario.cfg.clone()
    };
    let fleet = Fleet::sim(cfg, fleet_scenario_config(n_replicas), SimSpec::default()).map_err(
        |e| Violation {
            seed,
            step: 0,
            message: format!("chunked fleet construction failed: {e}"),
        },
    )?;
    run_fleet_scenario(&scenario, fleet, None)
}

/// Run a fully *adversarial* scenario ([`generate_scenario`] — slow
/// readers, stalls, disconnects, step-indexed cancels, idle timeouts)
/// with `decode_chunk = chunk`. Behavior is **not** expected to match
/// the unchunked run here (the harness scripts are step-indexed and the
/// step axis compresses); what must hold is that all five oracles pass
/// and the run is byte-reproducible at the same chunk value.
pub fn run_scenario_chunked_adversarial(
    seed: u64,
    chunk: usize,
) -> Result<ScenarioReport, Violation> {
    let scenario = generate_scenario(seed);
    let cfg = EngineConfig {
        decode_chunk: chunk,
        ..scenario.cfg.clone()
    };
    let engine = SimEngine::new(cfg, SimSpec::default()).map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("chunked engine construction failed: {e}"),
    })?;
    run_with_hook(&scenario, engine, &mut |_, _| {})
}

/// Run a scenario on any [`Backend`] (the engine must have been built
/// from `scenario.cfg`). The differential lockstep test drives the sim
/// and stub backends through the same scenario and asserts equal
/// reports.
pub fn run_scenario_on<B: Backend>(
    scenario: &Scenario,
    engine: EngineCore<B>,
) -> Result<ScenarioReport, Violation> {
    run_with_hook(scenario, engine, &mut |_, _| {})
}

/// Stamp a violation with the newest flight-recorder entries, so a
/// failing seed ships its own black box. The dump is stamped from the
/// virtual clock, so a replay still fails byte-identically.
fn with_flight<B: Backend>(engine: &EngineCore<B>, mut v: Violation) -> Violation {
    let dump = engine.flight_text(FLIGHT_DUMP_LINES);
    if !dump.is_empty() {
        v.message
            .push_str("\n  flight recorder (newest entries, oldest first):\n");
        v.message.push_str(&dump);
    }
    v
}

/// Like [`run_scenario_on`], with a per-step hook called right after
/// the engine step and *before* the oracles — the fault-injection port
/// the `#[cfg(test)]` double-free test uses.
fn run_with_hook<B: Backend>(
    scenario: &Scenario,
    mut engine: EngineCore<B>,
    hook: &mut dyn FnMut(&mut EngineCore<B>, usize),
) -> Result<ScenarioReport, Violation> {
    let seed = scenario.seed;
    let violation = |step: usize, message: String| Violation {
        seed,
        step,
        message,
    };
    engine.enable_trace();
    // The action-reorder stream is independent of the scenario stream,
    // but equally seed-determined.
    let mut shuffle = Rng::seed_from_u64(seed ^ 0xF0F0_1234_5678_9ABC);
    let n = scenario.clients.len();
    let mut states: Vec<ClientState> = (0..n).map(|_| ClientState::new()).collect();
    let mut emitted: HashMap<SeqId, Vec<u32>> = HashMap::new();
    let mut finished_trace: HashMap<SeqId, (FinishReason, Usage)> = HashMap::new();
    let mut fingerprint: u64 = splitmix64(seed);
    let (mut pauses, mut resumes, mut expired) = (0u64, 0u64, 0u64);

    let mut step = 0usize;
    loop {
        if step > MAX_STEPS {
            return Err(with_flight(
                &engine,
                violation(step, "scenario did not terminate (liveness wedge)".into()),
            ));
        }
        let cleanup = step >= scenario.horizon;

        // Arrivals due this step.
        for (i, c) in scenario.clients.iter().enumerate() {
            if c.arrive_step == step && !states[i].submitted {
                let h = engine
                    .submit(c.request())
                    .map_err(|e| violation(step, format!("submit rejected: {e}")))?;
                states[i].engine_id = Some(h.id);
                states[i].handle = Some(h);
                states[i].submitted = true;
            }
        }

        // Scripted client actions, applied in a seed-shuffled order
        // each step (the "reordered client actions" fault plane).
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            order.swap(i, shuffle.gen_range(0, i));
        }
        for &i in &order {
            let c = &scenario.clients[i];
            if c.cancel_at == Some(step) {
                if let Some(id) = states[i].engine_id {
                    let _ = engine.cancel(id);
                }
            }
            if states[i].dropped || states[i].handle.is_none() {
                continue;
            }
            let reader = if cleanup { Reader::Eager } else { c.reader };
            states[i].read_scripted(reader, step);
        }

        // Admin bulk-cancel of one tenant, across "connections".
        if let Some((admin_step, tenant)) = &scenario.admin_cancel {
            if *admin_step == step {
                for (i, c) in scenario.clients.iter().enumerate() {
                    if &c.tenant == tenant && states[i].finished.is_none() {
                        if let Some(id) = states[i].engine_id {
                            let _ = engine.cancel(id);
                        }
                    }
                }
            }
        }

        // One engine step (skip when truly idle; virtual time still
        // passes for the harness via the step counter).
        if !engine.is_idle() {
            engine
                .step()
                .map_err(|e| violation(step, format!("engine step failed: {e}")))?;
        }

        // Fault-injection port (no-op in normal runs).
        hook(&mut engine, step);
        // Every oracle below stamps its violation with the engine's
        // flight recorder via [`with_flight`].

        // Trace-driven oracles (3 and 4) + fingerprint.
        for ev in engine.take_trace() {
            fingerprint = fold_event(fingerprint, &ev);
            match &ev {
                TraceEvent::Token { id, token } => {
                    emitted.entry(*id).or_default().push(*token);
                }
                TraceEvent::Paused { .. } => pauses += 1,
                TraceEvent::Resumed { .. } => resumes += 1,
                TraceEvent::Expired { .. } => expired += 1,
                TraceEvent::Preempted { id, priority, pool } => {
                    check_preemption(*id, *priority, pool)
                        .map_err(|m| with_flight(&engine, violation(step, m)))?;
                }
                TraceEvent::AdmissionRelief {
                    id,
                    priority,
                    waiter_priority,
                } => {
                    if priority >= waiter_priority {
                        return Err(with_flight(
                            &engine,
                            violation(
                                step,
                                format!(
                                    "admission relief preempted seq {id} (priority {priority}) \
                                     for a waiter of priority {waiter_priority}"
                                ),
                            ),
                        ));
                    }
                }
                TraceEvent::Finished { id, reason, usage } => {
                    if finished_trace.insert(*id, (*reason, *usage)).is_some() {
                        return Err(with_flight(
                            &engine,
                            violation(step, format!("seq {id} emitted two finish events")),
                        ));
                    }
                    let n_emitted = emitted.get(id).map(Vec::len).unwrap_or(0);
                    check_usage(usage, n_emitted).map_err(|m| {
                        with_flight(&engine, violation(step, format!("seq {id}: {m}")))
                    })?;
                }
                TraceEvent::Admitted { .. } => {}
            }
        }

        // Oracle 1: refcount conservation, every step.
        check_kv_conservation(&engine.audit())
            .map_err(|m| with_flight(&engine, violation(step, m)))?;

        // Oracle 2 (bounds half): live buffers never exceed capacity.
        for (i, s) in states.iter().enumerate() {
            if let Some(h) = &s.handle {
                if h.events.buffered() > h.capacity() {
                    return Err(with_flight(
                        &engine,
                        violation(
                            step,
                            format!(
                                "client {i} buffers {} events over capacity {}",
                                h.events.buffered(),
                                h.capacity()
                            ),
                        ),
                    ));
                }
            }
        }

        // Oracle 5: span conservation — every request timeline the
        // engine retains (live and finished) is a legal, monotone
        // state machine whose finished phases partition its total, and
        // the span counters agree with the admission/finish accounting.
        {
            let spans = engine.spans();
            let mut all: Vec<_> = spans.active().chain(spans.completed()).collect();
            all.sort_by_key(|s| s.id);
            for s in all {
                s.check()
                    .map_err(|m| with_flight(&engine, violation(step, m)))?;
            }
            if spans.spans_admitted != engine.metrics.requests_admitted
                || spans.spans_finished != engine.metrics.requests_finished
            {
                return Err(with_flight(
                    &engine,
                    violation(
                        step,
                        format!(
                            "span counters drifted from engine accounting: \
                             admitted {} vs {}, finished {} vs {}",
                            spans.spans_admitted,
                            engine.metrics.requests_admitted,
                            spans.spans_finished,
                            engine.metrics.requests_finished
                        ),
                    ),
                ));
            }
        }

        // Termination: everything arrived and the engine drained.
        let all_submitted = states.iter().all(|s| s.submitted);
        if all_submitted && engine.is_idle() {
            for s in states.iter_mut() {
                s.receive(usize::MAX);
            }
            break;
        }
        step += 1;
    }

    // End-of-run oracles.
    let audit = engine.audit();
    if !audit.live.is_empty() || audit.queued != 0 {
        return Err(with_flight(
            &engine,
            violation(step, "idle engine still holds sequences".into()),
        ));
    }
    let mut total_generated = 0u64;
    for (_, usage) in finished_trace.values() {
        total_generated += usage.generated_tokens as u64;
    }
    if total_generated != engine.metrics.tokens_generated {
        return Err(with_flight(
            &engine,
            violation(
                step,
                format!(
                    "usage sum {total_generated} != engine token counter {}",
                    engine.metrics.tokens_generated
                ),
            ),
        ));
    }
    for (i, s) in states.iter().enumerate() {
        if s.dropped {
            continue; // disconnected clients forfeit delivery checks
        }
        let Some(id) = s.engine_id else { continue };
        if s.finished.is_none() {
            return Err(with_flight(
                &engine,
                violation(
                    step,
                    format!("client {i} (seq {id}) never received a finish event"),
                ),
            ));
        }
        // Oracle 2 (lossless half): the retained client drained exactly
        // the emitted token sequence — nothing lost across
        // pause/resume, nothing reordered, nothing duplicated.
        let want = emitted.get(&id).cloned().unwrap_or_default();
        if s.drained != want {
            return Err(with_flight(
                &engine,
                violation(
                    step,
                    format!(
                        "client {i} (seq {id}) drained {} tokens but the engine emitted {} \
                         (loss or reorder across pause/resume)",
                        s.drained.len(),
                        want.len()
                    ),
                ),
            ));
        }
        fingerprint = fold(fingerprint, s.drained.len() as u64);
    }

    Ok(ScenarioReport {
        seed,
        steps: step,
        requests: n,
        finished: engine.metrics.requests_finished,
        preemptions: engine.metrics.preemptions,
        pauses,
        resumes,
        expired,
        disconnects: engine.metrics.client_disconnects,
        cancellations: engine.metrics.cancellations,
        tokens_generated: engine.metrics.tokens_generated,
        fingerprint,
    })
}

// ---------------------------------------------------------------------
// Crash recovery
// ---------------------------------------------------------------------

/// Outcome of one crash-recovery run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CrashRecoveryReport {
    pub seed: u64,
    pub crash_step: usize,
    /// Requests whose terminal event was delivered before the crash.
    pub finished_before_crash: usize,
    /// Requests resubmitted to the rebuilt core from the registry.
    pub resubmitted: usize,
    /// Requests the rebuilt core finished (includes resubmissions and
    /// post-crash arrivals).
    pub finished_after_recovery: u64,
}

/// Script a mid-run engine crash: drive a seeded scenario while
/// mirroring every submission in a server-side [`RequestRegistry`],
/// drop the whole core at a seed-derived step, rebuild a fresh one, and
/// resubmit everything the registry still lists as in flight. The KV
/// refcount oracle runs on every step of both engine lives; every
/// client retained after recovery must still receive a terminal event,
/// and the rebuilt core must drain to a clean audit.
pub fn run_crash_recovery(seed: u64) -> Result<CrashRecoveryReport, Violation> {
    let scenario = generate_scenario(seed);
    let violation = |step: usize, message: String| Violation {
        seed,
        step,
        message,
    };
    let build = |step: usize| {
        SimEngine::new(scenario.cfg.clone(), SimSpec::default())
            .map_err(|e| violation(step, format!("engine construction failed: {e}")))
    };
    let mut engine = build(0)?;
    let registry = RequestRegistry::new();
    let n = scenario.clients.len();
    let mut states: Vec<ClientState> = (0..n).map(|_| ClientState::new()).collect();
    let mut gids: Vec<Option<String>> = vec![None; n];
    // Crash while the scenario is still busy: after the first arrivals,
    // well before the cleanup horizon.
    let crash_step = 8 + (seed as usize % 24);

    // Phase A: the scripted world, up to the crash.
    for step in 0..crash_step {
        for (i, c) in scenario.clients.iter().enumerate() {
            if c.arrive_step == step && !states[i].submitted {
                let h = engine
                    .submit(c.request())
                    .map_err(|e| violation(step, format!("submit rejected: {e}")))?;
                gids[i] = Some(registry.register(h.id, &c.tenant, c.priority));
                states[i].engine_id = Some(h.id);
                states[i].handle = Some(h);
                states[i].submitted = true;
            }
        }
        for i in 0..n {
            if states[i].dropped || states[i].handle.is_none() {
                continue;
            }
            states[i].read_scripted(scenario.clients[i].reader, step);
            if states[i].finished.is_some() {
                // The terminal event was delivered: the server prunes
                // the registry entry (same rule as `pump_events`).
                if let Some(gid) = &gids[i] {
                    registry.remove(gid);
                }
            }
        }
        if !engine.is_idle() {
            engine
                .step()
                .map_err(|e| violation(step, format!("engine step failed: {e}")))?;
        }
        check_kv_conservation(&engine.audit()).map_err(|m| violation(step, m))?;
    }
    let finished_before_crash = states.iter().filter(|s| s.finished.is_some()).count();

    // The crash: the core is gone, along with every in-flight stream.
    drop(engine);
    for s in states.iter_mut() {
        s.handle = None;
    }

    // Recovery: a fresh core; the registry tells the server side which
    // requests never delivered a terminal event — those are resubmitted
    // (a request that finished before the crash stays finished). Late
    // arrivals that never reached the old core are submitted too.
    let mut engine = build(crash_step)?;
    let mut resubmitted = 0usize;
    for (i, c) in scenario.clients.iter().enumerate() {
        let lost_inflight = gids[i]
            .as_ref()
            .map(|g| registry.resolve(g).is_some())
            .unwrap_or(false);
        if states[i].dropped || states[i].finished.is_some() {
            continue;
        }
        if lost_inflight || !states[i].submitted {
            let h = engine
                .submit(c.request())
                .map_err(|e| violation(crash_step, format!("resubmit rejected: {e}")))?;
            if let Some(gid) = gids[i].take() {
                registry.remove(&gid);
                resubmitted += 1;
            }
            gids[i] = Some(registry.register(h.id, &c.tenant, c.priority));
            states[i].engine_id = Some(h.id);
            states[i].handle = Some(h);
            states[i].submitted = true;
        }
    }

    // Phase B: drain the rebuilt core with eager readers; the oracles
    // must hold exactly as on a clean run.
    let mut step = crash_step;
    while !engine.is_idle() {
        if step > MAX_STEPS {
            return Err(violation(
                step,
                "recovered scenario did not terminate (liveness wedge)".into(),
            ));
        }
        engine
            .step()
            .map_err(|e| violation(step, format!("engine step failed: {e}")))?;
        for s in states.iter_mut() {
            s.receive(usize::MAX);
        }
        check_kv_conservation(&engine.audit()).map_err(|m| violation(step, m))?;
        step += 1;
    }
    for s in states.iter_mut() {
        s.receive(usize::MAX);
    }

    // End-state oracles: clean audit, every retained client finished.
    let audit = engine.audit();
    if !audit.live.is_empty() || audit.queued != 0 {
        return Err(violation(step, "idle engine still holds sequences".into()));
    }
    for (i, s) in states.iter().enumerate() {
        if s.dropped {
            continue;
        }
        if s.finished.is_none() {
            return Err(violation(
                step,
                format!("client {i} never received a finish event after recovery"),
            ));
        }
        if let Some(gid) = &gids[i] {
            registry.remove(gid);
        }
    }

    Ok(CrashRecoveryReport {
        seed,
        crash_step,
        finished_before_crash,
        resubmitted,
        finished_after_recovery: engine.metrics.requests_finished,
    })
}

// ---------------------------------------------------------------------
// Fleet scenarios
// ---------------------------------------------------------------------

/// Fleet configuration the fleet scenarios run under: cache-aware
/// routing with the default affinity/balance tradeoff, no fleet-level
/// tenant limits (the scenario's own quota planes stay in charge).
fn fleet_scenario_config(n_replicas: usize) -> FleetConfig {
    FleetConfig {
        n_replicas,
        policy: RoutePolicy::CacheAware,
        ..FleetConfig::default()
    }
}

/// Run a seeded scenario against an `n_replicas` sim fleet, all five
/// oracles armed per live replica. With `n_replicas == 1` the report —
/// fingerprint included — must equal [`run_scenario`]'s byte for byte
/// (the fleet layer is transparent); `tests/fleet.rs` asserts this
/// over the seed matrix.
pub fn run_scenario_fleet(seed: u64, n_replicas: usize) -> Result<ScenarioReport, Violation> {
    let scenario = generate_scenario(seed);
    let fleet = Fleet::sim(
        scenario.cfg.clone(),
        fleet_scenario_config(n_replicas),
        SimSpec::default(),
    )
    .map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("fleet construction failed: {e}"),
    })?;
    run_fleet_scenario(&scenario, fleet, None)
}

/// Like [`run_scenario_fleet`], but a seed-derived replica is killed at
/// a seed-derived step while the scenario is busy: its in-flight
/// requests are resubmitted to the survivors and their client streams
/// rebound. The oracles must hold on every step of the reduced fleet;
/// no request may be lost or finish twice. Panics if `n_replicas < 2`
/// (a kill needs a survivor).
pub fn run_replica_kill(seed: u64, n_replicas: usize) -> Result<ScenarioReport, Violation> {
    assert!(n_replicas >= 2, "replica-kill scenarios need a survivor");
    let scenario = generate_scenario(seed);
    let fleet = Fleet::sim(
        scenario.cfg.clone(),
        fleet_scenario_config(n_replicas),
        SimSpec::default(),
    )
    .map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("fleet construction failed: {e}"),
    })?;
    // Kill while the scenario is still busy (same window the crash-
    // recovery scenario uses); which replica dies is seed-derived too.
    let kill_step = 8 + (seed as usize % 24);
    let replica = (seed as usize / 7) % n_replicas;
    run_fleet_scenario(&scenario, fleet, Some((kill_step, replica)))
}

/// Run one seeded scenario on `EngineCore<ShardedBackend<SimBackend>>`
/// with `shards` simulated tensor-parallel lanes. Sharding must be
/// invisible to scheduling, so for every `shards` the report —
/// fingerprint included — must equal [`run_scenario`]'s byte for byte;
/// `tests/differential_backends.rs` asserts this over the seed matrix.
pub fn run_scenario_sharded(seed: u64, shards: usize) -> Result<ScenarioReport, Violation> {
    let scenario = generate_scenario(seed);
    let engine = EngineCore::with_backend(
        ShardedBackend::new(SimBackend::new(SimSpec::default()), shards),
        scenario.cfg.clone(),
        SimClock::manual(),
    )
    .map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("sharded engine construction failed: {e}"),
    })?;
    run_with_hook(&scenario, engine, &mut |_, _| {})
}

/// [`run_scenario_fleet`] over replicas whose backend is
/// [`ShardedBackend<SimBackend>`] with `shards` lanes each — the
/// composition the fleet layer must stay transparent to.
pub fn run_scenario_fleet_sharded(
    seed: u64,
    n_replicas: usize,
    shards: usize,
) -> Result<ScenarioReport, Violation> {
    let scenario = generate_scenario(seed);
    let fleet = Fleet::sharded_sim(
        scenario.cfg.clone(),
        fleet_scenario_config(n_replicas),
        SimSpec::default(),
        shards,
    )
    .map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("sharded fleet construction failed: {e}"),
    })?;
    run_fleet_scenario(&scenario, fleet, None)
}

/// [`run_replica_kill`] over sharded replicas: the same seed-derived
/// kill step and victim replica, `shards` lanes per replica. Panics if
/// `n_replicas < 2`.
pub fn run_replica_kill_sharded(
    seed: u64,
    n_replicas: usize,
    shards: usize,
) -> Result<ScenarioReport, Violation> {
    assert!(n_replicas >= 2, "replica-kill scenarios need a survivor");
    let scenario = generate_scenario(seed);
    let fleet = Fleet::sharded_sim(
        scenario.cfg.clone(),
        fleet_scenario_config(n_replicas),
        SimSpec::default(),
        shards,
    )
    .map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("sharded fleet construction failed: {e}"),
    })?;
    let kill_step = 8 + (seed as usize % 24);
    let replica = (seed as usize / 7) % n_replicas;
    run_fleet_scenario(&scenario, fleet, Some((kill_step, replica)))
}

/// Per-event bookkeeping shared by every replica's trace drain —
/// exactly the fold and oracle checks [`run_with_hook`] applies, kept
/// free of fleet borrows so the caller can stamp violations with
/// flight dumps.
struct FleetObs {
    emitted: HashMap<SeqId, Vec<u32>>,
    finished_trace: HashMap<SeqId, (FinishReason, Usage)>,
    fingerprint: u64,
    pauses: u64,
    resumes: u64,
    expired: u64,
    /// Tokens dead replicas had emitted for requests that were then
    /// resubmitted — lost mid-stream, and accounted against the fleet
    /// token counter in the end-of-run usage oracle.
    lost_tokens: u64,
}

impl FleetObs {
    fn process(&mut self, ev: &TraceEvent) -> Result<(), String> {
        self.fingerprint = fold_event(self.fingerprint, ev);
        match ev {
            TraceEvent::Token { id, token } => {
                self.emitted.entry(*id).or_default().push(*token);
            }
            TraceEvent::Paused { .. } => self.pauses += 1,
            TraceEvent::Resumed { .. } => self.resumes += 1,
            TraceEvent::Expired { .. } => self.expired += 1,
            TraceEvent::Preempted { id, priority, pool } => {
                check_preemption(*id, *priority, pool)?;
            }
            TraceEvent::AdmissionRelief {
                id,
                priority,
                waiter_priority,
            } => {
                if priority >= waiter_priority {
                    return Err(format!(
                        "admission relief preempted seq {id} (priority {priority}) \
                         for a waiter of priority {waiter_priority}"
                    ));
                }
            }
            TraceEvent::Finished { id, reason, usage } => {
                if self.finished_trace.insert(*id, (*reason, *usage)).is_some() {
                    return Err(format!("seq {id} emitted two finish events"));
                }
                let n_emitted = self.emitted.get(id).map(Vec::len).unwrap_or(0);
                check_usage(usage, n_emitted).map_err(|m| format!("seq {id}: {m}"))?;
            }
            TraceEvent::Admitted { .. } => {}
        }
        Ok(())
    }
}

/// Concatenated flight dumps of every live replica, for violation
/// reports (a dead replica's recorder died with it).
fn fleet_flight<B: Backend>(fleet: &Fleet<B>, mut v: Violation) -> Violation {
    let mut dump = String::new();
    for k in 0..fleet.n_replicas() {
        if let Some(core) = fleet.core(k) {
            let text = core.flight_text(FLIGHT_DUMP_LINES);
            if !text.is_empty() {
                dump.push_str(&format!("  -- replica {k} --\n"));
                dump.push_str(&text);
            }
        }
    }
    if !dump.is_empty() {
        v.message
            .push_str("\n  flight recorders (newest entries, oldest first):\n");
        v.message.push_str(&dump);
    }
    v
}

/// The fleet twin of [`run_with_hook`]: statement-for-statement the
/// same scripted world (arrivals, seed-shuffled client actions, admin
/// cancel, one step, trace-driven oracles, per-step invariants,
/// termination), driving a [`Fleet`] instead of a bare core. `kill`
/// optionally names `(step, replica)` to kill mid-run.
fn run_fleet_scenario<B: Backend>(
    scenario: &Scenario,
    mut fleet: Fleet<B>,
    kill: Option<(usize, usize)>,
) -> Result<ScenarioReport, Violation> {
    let seed = scenario.seed;
    let violation = |step: usize, message: String| Violation {
        seed,
        step,
        message,
    };
    fleet.enable_trace();
    let mut shuffle = Rng::seed_from_u64(seed ^ 0xF0F0_1234_5678_9ABC);
    let n = scenario.clients.len();
    let mut states: Vec<ClientState> = (0..n).map(|_| ClientState::new()).collect();
    let mut obs = FleetObs {
        emitted: HashMap::new(),
        finished_trace: HashMap::new(),
        fingerprint: splitmix64(seed),
        pauses: 0,
        resumes: 0,
        expired: 0,
        lost_tokens: 0,
    };
    let mut killed = false;

    let mut step = 0usize;
    loop {
        if step > MAX_STEPS {
            return Err(fleet_flight(
                &fleet,
                violation(step, "scenario did not terminate (liveness wedge)".into()),
            ));
        }
        let cleanup = step >= scenario.horizon;

        // Arrivals due this step.
        for (i, c) in scenario.clients.iter().enumerate() {
            if c.arrive_step == step && !states[i].submitted {
                let h = fleet
                    .submit(c.request())
                    .map_err(|e| violation(step, format!("submit rejected: {e}")))?;
                states[i].engine_id = Some(h.id);
                states[i].handle = Some(h);
                states[i].submitted = true;
            }
        }

        // Scripted client actions in the seed-shuffled order.
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            order.swap(i, shuffle.gen_range(0, i));
        }
        for &i in &order {
            let c = &scenario.clients[i];
            if c.cancel_at == Some(step) {
                if let Some(id) = states[i].engine_id {
                    let _ = fleet.cancel(id);
                }
            }
            if states[i].dropped || states[i].handle.is_none() {
                continue;
            }
            let reader = if cleanup { Reader::Eager } else { c.reader };
            states[i].read_scripted(reader, step);
        }

        // Admin bulk-cancel of one tenant, across "connections".
        if let Some((admin_step, tenant)) = &scenario.admin_cancel {
            if *admin_step == step {
                for (i, c) in scenario.clients.iter().enumerate() {
                    if &c.tenant == tenant && states[i].finished.is_none() {
                        if let Some(id) = states[i].engine_id {
                            let _ = fleet.cancel(id);
                        }
                    }
                }
            }
        }

        // The scripted replica death. Trace emitted so far (including
        // cancels applied this step) is folded first, so the victim
        // accounting below sees every token the doomed replica ever
        // streamed.
        if let Some((kill_step, replica)) = kill {
            if step == kill_step && !killed {
                killed = true;
                for r in 0..fleet.n_replicas() {
                    for ev in fleet.take_trace_of(r) {
                        obs.process(&ev)
                            .map_err(|m| fleet_flight(&fleet, violation(step, m)))?;
                    }
                }
                let moved = fleet
                    .kill(replica)
                    .map_err(|e| violation(step, format!("kill failed: {e}")))?;
                obs.fingerprint = fold(obs.fingerprint, moved.len() as u64);
                for (old_id, handle) in moved {
                    obs.lost_tokens +=
                        obs.emitted.get(&old_id).map(Vec::len).unwrap_or(0) as u64;
                    let owner = states.iter().position(|s| s.engine_id == Some(old_id));
                    match owner {
                        Some(i) if !states[i].dropped => {
                            // Rebind the client to its re-run: the new
                            // stream restarts the token sequence.
                            states[i].engine_id = Some(handle.id);
                            states[i].handle = Some(handle);
                            states[i].drained.clear();
                            states[i].finished = None;
                        }
                        // A disconnected (or unknown) owner stays gone:
                        // dropping the handle tells the survivor to
                        // reap the re-run as a disconnect.
                        _ => drop(handle),
                    }
                }
            }
        }

        // One fleet step (skip when truly idle, as the bare runner
        // does).
        if !fleet.is_idle() {
            fleet
                .step()
                .map_err(|e| violation(step, format!("fleet step failed: {e}")))?;
        }

        // Trace-driven oracles (3 and 4) + fingerprint, replica by
        // replica in index order.
        for r in 0..fleet.n_replicas() {
            for ev in fleet.take_trace_of(r) {
                obs.process(&ev)
                    .map_err(|m| fleet_flight(&fleet, violation(step, m)))?;
            }
        }

        // Oracle 1: refcount conservation on every live replica.
        for r in 0..fleet.n_replicas() {
            if let Some(core) = fleet.core(r) {
                check_kv_conservation(&core.audit()).map_err(|m| {
                    fleet_flight(&fleet, violation(step, format!("replica {r}: {m}")))
                })?;
            }
        }

        // Oracle 2 (bounds half): live buffers never exceed capacity.
        for (i, s) in states.iter().enumerate() {
            if let Some(h) = &s.handle {
                if h.events.buffered() > h.capacity() {
                    return Err(fleet_flight(
                        &fleet,
                        violation(
                            step,
                            format!(
                                "client {i} buffers {} events over capacity {}",
                                h.events.buffered(),
                                h.capacity()
                            ),
                        ),
                    ));
                }
            }
        }

        // Oracle 5: span conservation per live replica.
        for r in 0..fleet.n_replicas() {
            let Some(core) = fleet.core(r) else { continue };
            let spans = core.spans();
            let mut all: Vec<_> = spans.active().chain(spans.completed()).collect();
            all.sort_by_key(|s| s.id);
            for s in all {
                s.check().map_err(|m| {
                    fleet_flight(&fleet, violation(step, format!("replica {r}: {m}")))
                })?;
            }
            if spans.spans_admitted != core.metrics.requests_admitted
                || spans.spans_finished != core.metrics.requests_finished
            {
                return Err(fleet_flight(
                    &fleet,
                    violation(
                        step,
                        format!(
                            "replica {r} span counters drifted from engine accounting: \
                             admitted {} vs {}, finished {} vs {}",
                            spans.spans_admitted,
                            core.metrics.requests_admitted,
                            spans.spans_finished,
                            core.metrics.requests_finished
                        ),
                    ),
                ));
            }
        }

        // Termination: everything arrived and the fleet drained.
        let all_submitted = states.iter().all(|s| s.submitted);
        if all_submitted && fleet.is_idle() {
            for s in states.iter_mut() {
                s.receive(usize::MAX);
            }
            break;
        }
        step += 1;
    }

    // End-of-run oracles, per live replica.
    for r in 0..fleet.n_replicas() {
        let Some(core) = fleet.core(r) else { continue };
        let audit = core.audit();
        if !audit.live.is_empty() || audit.queued != 0 {
            return Err(fleet_flight(
                &fleet,
                violation(step, format!("idle replica {r} still holds sequences")),
            ));
        }
    }
    // Usage conservation, fleet-wide: the merged token counter (dead
    // replicas included) equals the finished usages plus the tokens
    // dead replicas streamed for requests that were resubmitted.
    let mut total_generated = 0u64;
    for (_, usage) in obs.finished_trace.values() {
        total_generated += usage.generated_tokens as u64;
    }
    if total_generated + obs.lost_tokens != fleet.metrics().tokens_generated {
        return Err(fleet_flight(
            &fleet,
            violation(
                step,
                format!(
                    "usage sum {total_generated} + {} lost != fleet token counter {}",
                    obs.lost_tokens,
                    fleet.metrics().tokens_generated
                ),
            ),
        ));
    }
    for (i, s) in states.iter().enumerate() {
        if s.dropped {
            continue; // disconnected clients forfeit delivery checks
        }
        let Some(id) = s.engine_id else { continue };
        if s.finished.is_none() {
            return Err(fleet_flight(
                &fleet,
                violation(
                    step,
                    format!("client {i} (seq {id}) never received a finish event"),
                ),
            ));
        }
        // Oracle 2 (lossless half), against the client's *current*
        // stream: a rebound victim restarts cleanly on its new id.
        let want = obs.emitted.get(&id).cloned().unwrap_or_default();
        if s.drained != want {
            return Err(fleet_flight(
                &fleet,
                violation(
                    step,
                    format!(
                        "client {i} (seq {id}) drained {} tokens but the engine emitted {} \
                         (loss or reorder across pause/resume)",
                        s.drained.len(),
                        want.len()
                    ),
                ),
            ));
        }
        obs.fingerprint = fold(obs.fingerprint, s.drained.len() as u64);
    }

    let m = fleet.metrics();
    Ok(ScenarioReport {
        seed,
        steps: step,
        requests: n,
        finished: m.requests_finished,
        preemptions: m.preemptions,
        pauses: obs.pauses,
        resumes: obs.resumes,
        expired: obs.expired,
        disconnects: m.client_disconnects,
        cancellations: m.cancellations,
        tokens_generated: m.tokens_generated,
        fingerprint: obs.fingerprint,
    })
}

/// Run a scenario with a double-free injected through the KV cache's
/// `#[cfg(test)]` fault hook at the first step where live KV exists.
/// The refcount oracle must catch it on that very step.
#[cfg(test)]
pub fn run_scenario_with_double_free(seed: u64) -> Result<ScenarioReport, Violation> {
    let scenario = generate_scenario(seed);
    let engine = SimEngine::new(scenario.cfg.clone(), SimSpec::default()).map_err(|e| Violation {
        seed,
        step: 0,
        message: format!("engine construction failed: {e}"),
    })?;
    let mut injected = false;
    run_with_hook(&scenario, engine, &mut |engine, _step| {
        if !injected {
            injected = engine.inject_double_free();
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_generation_is_deterministic() {
        let a = generate_scenario(42);
        let b = generate_scenario(42);
        assert_eq!(a.cfg.kv_total_blocks, b.cfg.kv_total_blocks);
        assert_eq!(a.clients.len(), b.clients.len());
        for (x, y) in a.clients.iter().zip(&b.clients) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.arrive_step, y.arrive_step);
        }
        let c = generate_scenario(43);
        assert!(
            a.clients.len() != c.clients.len()
                || a.clients.iter().zip(&c.clients).any(|(x, y)| {
                    x.prompt != y.prompt
                        || x.arrive_step != y.arrive_step
                        || x.priority != y.priority
                }),
            "different seeds must differ"
        );
    }

    #[test]
    fn same_seed_reproduces_byte_identically() {
        for seed in [1u64, 7, 23] {
            let a = run_scenario(seed).expect("scenario passes oracles");
            let b = run_scenario(seed).expect("scenario passes oracles");
            assert_eq!(a, b, "seed {seed} must reproduce exactly");
            assert_eq!(a.fingerprint, b.fingerprint);
        }
    }

    #[test]
    fn injected_double_free_is_caught_and_reproduces() {
        // Find a seed whose scenario has live KV (they all do once a
        // request is admitted); the refcount oracle must report the
        // fault, and the failure must reproduce byte-identically.
        let seed = 3u64;
        let first = run_scenario_with_double_free(seed)
            .expect_err("double free must trip the refcount oracle");
        assert!(
            first.message.contains("refcount") || first.message.contains("double free"),
            "unexpected violation: {first}"
        );
        let again = run_scenario_with_double_free(seed).expect_err("must fail again");
        assert_eq!(first, again, "fault replay must be byte-identical");
        // The clean run of the same seed passes — the fault hook, not
        // the scenario, is what broke the invariant.
        run_scenario(seed).expect("clean run passes");
    }

    #[test]
    fn single_replica_fleet_report_matches_bare_engine() {
        for seed in [1u64, 7, 23] {
            let bare = run_scenario(seed).expect("bare scenario passes");
            let fleet = run_scenario_fleet(seed, 1).expect("fleet scenario passes");
            assert_eq!(bare, fleet, "seed {seed}: a fleet of one must be transparent");
        }
    }

    #[test]
    fn fleet_scenarios_pass_oracles_and_reproduce() {
        for seed in [2u64, 9, 31] {
            let a = run_scenario_fleet(seed, 3).expect("fleet scenario passes oracles");
            let b = run_scenario_fleet(seed, 3).expect("fleet scenario passes oracles");
            assert_eq!(a, b, "seed {seed} must reproduce exactly");
            assert!(a.finished > 0, "seed {seed} finishes work");
        }
    }

    #[test]
    fn replica_kill_scenarios_pass_oracles_and_reproduce() {
        for seed in [1u64, 5, 12, 27] {
            let a = run_replica_kill(seed, 2).expect("kill scenario passes oracles");
            let b = run_replica_kill(seed, 2).expect("kill scenario passes oracles");
            assert_eq!(a, b, "seed {seed} must reproduce exactly");
        }
        // Wider fleets survive the same seeds.
        run_replica_kill(5, 3).expect("three-replica kill passes");
    }

    #[test]
    fn violation_prints_seed_and_replay_command() {
        let v = Violation {
            seed: 77,
            step: 12,
            message: "block 3: refcount 0 != 1 visible owners".into(),
        };
        let text = v.to_string();
        assert!(text.contains("seed 77"));
        assert!(text.contains("step 12"));
        assert!(text.contains("--seed 77"), "replay command present: {text}");
    }

    #[test]
    fn kv_conservation_oracle_rejects_leaks() {
        use crate::core::EngineAudit;
        use crate::kvcache::KvAudit;
        // A block referenced by a sequence but with refcount 0 and on
        // the free list: the double-free shape.
        let audit = EngineAudit {
            kv: KvAudit {
                total_blocks: 2,
                free_list: vec![0, 1],
                refcounts: vec![0, 0],
                seq_blocks: vec![(1, vec![0])],
            },
            tree_blocks: vec![],
            live: vec![],
            queued: 0,
        };
        assert!(check_kv_conservation(&audit).is_err());
        // A consistent audit passes.
        let audit = EngineAudit {
            kv: KvAudit {
                total_blocks: 2,
                free_list: vec![1],
                refcounts: vec![1, 0],
                seq_blocks: vec![(1, vec![0])],
            },
            tree_blocks: vec![],
            live: vec![],
            queued: 0,
        };
        assert!(check_kv_conservation(&audit).is_ok());
    }

    #[test]
    fn violation_reports_carry_the_flight_recorder() {
        // The injected fault trips the refcount oracle; the report must
        // ship the engine's black box alongside the message.
        let v = run_scenario_with_double_free(3)
            .expect_err("double free must trip the refcount oracle");
        assert!(
            v.message.contains("flight recorder"),
            "violation ships the flight dump: {v}"
        );
        assert!(v.message.contains("submitted id="), "dump has entries: {v}");
    }

    #[test]
    fn perf_trajectory_report_is_byte_identical_and_complete() {
        use crate::bench_support::{perf_trajectory_report, PERF_TRAJECTORY_SEED};
        use crate::util::json::Json;
        let a = perf_trajectory_report(PERF_TRAJECTORY_SEED).expect("harness runs");
        let b = perf_trajectory_report(PERF_TRAJECTORY_SEED).expect("harness runs");
        assert_eq!(
            a.to_string(),
            b.to_string(),
            "BENCH_serving.json must be byte-identical across runs of the same seed"
        );
        for key in [
            "tokens_per_sec",
            "steps_per_sec",
            "ttft_p50_us",
            "ttft_p99_us",
            "inter_token_p50_us",
            "inter_token_p99_us",
            "prefix_hit_rate",
            "step_overhead",
        ] {
            assert!(a.get(key).is_some(), "report missing key {key}");
        }
        // The virtual clock gives every request a nonzero TTFT and the
        // run a nonzero throughput.
        assert!(a.get("ttft_p50_us").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(a.get("tokens_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn chunked_scenarios_match_unchunked_behavior() {
        for seed in [1u64, 7, 23] {
            let base = run_scenario_chunked(seed, 1).expect("chunk-1 baseline passes");
            for chunk in [2usize, 4, 8] {
                let c = run_scenario_chunked(seed, chunk).expect("chunked run passes oracles");
                assert_eq!(
                    behavior_key(&base),
                    behavior_key(&c),
                    "seed {seed} chunk {chunk}: behavior must be chunk-invariant"
                );
                assert!(
                    c.steps <= base.steps,
                    "seed {seed} chunk {chunk}: chunking must never add steps"
                );
            }
        }
    }

    #[test]
    fn chunked_adversarial_runs_pass_oracles_and_reproduce() {
        // Step-indexed client scripts mean behavior legitimately shifts
        // under chunking; the oracles and same-chunk determinism are
        // what must survive the adversarial worlds.
        for seed in [2u64, 9] {
            for chunk in [2usize, 4] {
                let a = run_scenario_chunked_adversarial(seed, chunk).expect("oracles pass");
                let b = run_scenario_chunked_adversarial(seed, chunk).expect("oracles pass");
                assert_eq!(a, b, "seed {seed} chunk {chunk} must reproduce exactly");
            }
        }
    }

    #[test]
    fn crash_recovery_reproduces_byte_identically() {
        for seed in [2u64, 5] {
            let a = run_crash_recovery(seed).expect("crash recovery passes oracles");
            let b = run_crash_recovery(seed).expect("crash recovery passes oracles");
            assert_eq!(a, b, "seed {seed} must reproduce exactly");
        }
    }
}
