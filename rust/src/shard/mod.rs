//! Sharded backend: simulated tensor-parallel lanes behind one
//! [`Backend`].
//!
//! [`ShardedBackend<B>`] wraps any compute backend and splits its dense
//! per-token KV state across `M` simulated device lanes: shard `s` owns
//! a contiguous element range of every token's K/V column (the balanced
//! partition [`slice_range`] — head/layer agnostic, so any `M` works
//! with any geometry). Every engine hook is delegated to the inner
//! backend *verbatim* and then mirrored per lane: the wrapper keeps a
//! per-shard dense copy of each batched sequence's KV slice, drives the
//! per-lane bookkeeping for `on_batch_join/leave/pause/resume`, and
//! accounts the collective points a real tensor-parallel decode step
//! would synchronize on — an **all-gather** of the attention output at
//! the end of attention, and an **all-reduce** of the vocab-parallel
//! logits partials at the head. Counts and bytes land in
//! [`ShardMetrics`]; modeled per-shard compute and link time build on
//! [`crate::hwmodel`] the way LIMINAL (arxiv 2507.14397) frames decode
//! lanes: a bandwidth/compute/synchronization budget per device.
//!
//! The headline invariant is that **sharding is invisible to
//! scheduling**: the wrapper never changes what the inner backend
//! returns (logits, offsets, exec times) and never touches the paged
//! [`KvCache`] beyond reads, so `EngineCore<ShardedBackend<SimBackend>>`
//! produces byte-identical `ScenarioReport` fingerprints to
//! `EngineCore<SimBackend>` on every seed for every `M` — which
//! `tests/differential_backends.rs` proves over the whole matrix, and
//! `tests/prop_shard.rs` strengthens by reconstructing the unsharded
//! dense state from the per-shard slices after every step
//! ([`ShardedBackend::verify_sharding`]). In the same spirit the
//! wrapper deliberately does **not** override
//! [`Backend::decode_grouped`]: grouped decode steps fall through the
//! trait default to the per-sequence path, so enabling
//! [`EngineConfig::grouped_decode`] on a sharded engine changes no
//! output and claims no savings (proved by
//! `grouped_decode_flag_is_invisible_through_the_default_delegation`).
//!
//! Budget model (all write-only — virtual time never feeds back into
//! scheduling): per decode call with `b` rows over `M` shards, each
//! shard runs `1/M` of the attention sweep
//! ([`crate::hwmodel::attention_decode_time`], async-unified softmax)
//! and a vocab-sliced logits GEMM
//! ([`crate::hwmodel::gemm_time`], flat ImplB over `ceil(V/M)`
//! columns); the collectives move `(M-1)·E·4` bytes per row for the
//! attention all-gather (`E` = elements per token column) and
//! `2·(M-1)·V·4` bytes per row for the ring all-reduce of logits, plus
//! a per-hop link latency. `M = 1` runs no collectives at all.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::time::Duration;

use crate::batching::{Admission, DecodeBatch};
use crate::config::EngineConfig;
use crate::core::{Backend, DecodeRun, LaneInput, PrefillRun};
use crate::dataflow::ImplKind;
use crate::error::{Error, Result};
use crate::hwmodel::{
    a100, attention_decode_time, attention_prefill_time, gemm_time, GpuProfile, SoftmaxScheme,
};
use crate::kvcache::{KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::router::Sequence;
use crate::util::clock::Clock;
use crate::util::json::Json;

/// The element range of each token's K/V column owned by shard `s` of
/// `shards`: the balanced contiguous partition of `[0, te)` (low shards
/// absorb the remainder). Ranges tile the column exactly:
/// `slice_range(te, m, s).1 == slice_range(te, m, s + 1).0`.
pub fn slice_range(te: usize, shards: usize, s: usize) -> (usize, usize) {
    (s * te / shards, (s + 1) * te / shards)
}

/// Per-shard link/compute budget (LIMINAL-style): every lane is one
/// `gpu`, lanes talk over links of `link_bw` bytes/s with
/// `link_latency_s` per ring hop. Purely descriptive — the budget
/// shapes [`ShardMetrics`] virtual times, never scheduling.
#[derive(Debug, Clone)]
pub struct ShardBudget {
    /// The device model every lane runs on.
    pub gpu: GpuProfile,
    /// Inter-shard link bandwidth in bytes/s (NVLink-class default).
    pub link_bw: f64,
    /// Per-hop link latency in seconds, charged per ring step.
    pub link_latency_s: f64,
}

impl Default for ShardBudget {
    fn default() -> Self {
        ShardBudget {
            gpu: a100(),
            link_bw: 300.0e9,
            link_latency_s: 5.0e-6,
        }
    }
}

/// Per-lane counters inside [`ShardMetrics`]: the hook-driving record
/// (every core hook fires once per lane) plus the lane's mirrored KV
/// footprint and owned element range.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardLaneMetrics {
    /// First element of this lane's token-column slice.
    pub elems_lo: u64,
    /// One past the last element of this lane's token-column slice.
    pub elems_hi: u64,
    /// `on_batch_join` calls driven through this lane.
    pub joins: u64,
    /// `on_batch_leave` calls driven through this lane.
    pub leaves: u64,
    /// `on_pause` calls driven through this lane.
    pub pauses: u64,
    /// `on_resume` calls driven through this lane.
    pub resumes: u64,
    /// Decode rows this lane processed (identical across lanes — every
    /// lane sees the whole batch).
    pub decode_rows: u64,
    /// K elements currently mirrored on this lane (V mirrors the same
    /// count again).
    pub kv_elems: u64,
}

/// Collective and budget accounting for a [`ShardedBackend`]. All
/// counters are exact functions of the observed batch shapes (see
/// `tests/prop_shard.rs` for the analytic formulas); the `_s` times are
/// modeled virtual seconds on the [`ShardBudget`], accumulated in a
/// fixed order so reports are byte-reproducible.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardMetrics {
    /// The lane count `M`.
    pub shards: u64,
    /// Successful prefill calls.
    pub prefills: u64,
    /// Successful decode calls.
    pub decode_calls: u64,
    /// Decode rows summed over calls.
    pub decode_rows: u64,
    /// Attention-output all-gather operations (one per row; zero at
    /// `M = 1`).
    pub allgather_ops: u64,
    /// Bytes moved by attention all-gathers.
    pub allgather_bytes: u64,
    /// Logits all-reduce operations (one per row; zero at `M = 1`).
    pub allreduce_ops: u64,
    /// Bytes moved by logits all-reduces (ring: `2·(M-1)·V·4` per row).
    pub allreduce_bytes: u64,
    /// Modeled per-shard critical-path compute time, all calls.
    pub compute_s: f64,
    /// Modeled collective (link) time, all calls.
    pub collective_s: f64,
    /// [`ShardMetrics::compute_s`] restricted to decode calls.
    pub decode_compute_s: f64,
    /// [`ShardMetrics::collective_s`] restricted to decode calls.
    pub decode_collective_s: f64,
    /// Per-lane counters, indexed by shard.
    pub per_shard: Vec<ShardLaneMetrics>,
}

impl ShardMetrics {
    fn new(shards: usize) -> Self {
        ShardMetrics {
            shards: shards as u64,
            per_shard: vec![ShardLaneMetrics::default(); shards],
            ..ShardMetrics::default()
        }
    }

    /// Stats-snapshot rendering. The `per_shard` object is keyed by
    /// shard index, so [`crate::obs::prometheus_text`] renders one
    /// labeled gauge family per numeric lane field
    /// (`fdpp_shard_<field>{shard="s"}`).
    pub fn to_json(&self) -> Json {
        let per_shard = Json::Obj(
            self.per_shard
                .iter()
                .enumerate()
                .map(|(s, l)| {
                    (
                        s.to_string(),
                        Json::obj(vec![
                            ("elems_lo", Json::Num(l.elems_lo as f64)),
                            ("elems_hi", Json::Num(l.elems_hi as f64)),
                            ("joins", Json::Num(l.joins as f64)),
                            ("leaves", Json::Num(l.leaves as f64)),
                            ("pauses", Json::Num(l.pauses as f64)),
                            ("resumes", Json::Num(l.resumes as f64)),
                            ("decode_rows", Json::Num(l.decode_rows as f64)),
                            ("kv_elems", Json::Num(l.kv_elems as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("shard_count", Json::Num(self.shards as f64)),
            ("prefills", Json::Num(self.prefills as f64)),
            ("decode_calls", Json::Num(self.decode_calls as f64)),
            ("decode_rows", Json::Num(self.decode_rows as f64)),
            ("allgather_ops", Json::Num(self.allgather_ops as f64)),
            ("allgather_bytes", Json::Num(self.allgather_bytes as f64)),
            ("allreduce_ops", Json::Num(self.allreduce_ops as f64)),
            ("allreduce_bytes", Json::Num(self.allreduce_bytes as f64)),
            ("compute_ms", Json::Num(self.compute_s * 1e3)),
            ("collective_ms", Json::Num(self.collective_s * 1e3)),
            ("decode_compute_ms", Json::Num(self.decode_compute_s * 1e3)),
            (
                "decode_collective_ms",
                Json::Num(self.decode_collective_s * 1e3),
            ),
            ("per_shard", per_shard),
        ])
    }
}

/// One per-lane hook event ([`ShardedBackend::take_hook_trace`]): for
/// every core-level hook the wrapper records `M` events, shards
/// ascending, so a lockstep test can pin the exact per-lane call order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardHook {
    /// A prefill ran for `id`.
    Prefill { shard: usize, id: SeqId },
    /// `id` joined the decode batch on `lane`.
    Join { shard: usize, id: SeqId, lane: usize },
    /// A decode call covered `rows` lanes.
    Decode { shard: usize, rows: usize },
    /// `id` left the decode batch (`shrank`: the bucket shrank).
    Leave { shard: usize, id: SeqId, shrank: bool },
    /// A sequence was parked by stream backpressure.
    Pause { shard: usize },
    /// A parked sequence rejoined the batch on `lane`.
    Resume { shard: usize, lane: usize },
}

impl ShardHook {
    /// The lane this event was recorded for.
    pub fn shard(&self) -> usize {
        match self {
            ShardHook::Prefill { shard, .. }
            | ShardHook::Join { shard, .. }
            | ShardHook::Decode { shard, .. }
            | ShardHook::Leave { shard, .. }
            | ShardHook::Pause { shard }
            | ShardHook::Resume { shard, .. } => *shard,
        }
    }

    /// This event re-addressed to another lane (group-shape checks in
    /// the lockstep test: `hooks[i + s] == hooks[i].at_shard(s)`).
    pub fn at_shard(&self, shard: usize) -> ShardHook {
        let mut h = self.clone();
        match &mut h {
            ShardHook::Prefill { shard: s, .. }
            | ShardHook::Join { shard: s, .. }
            | ShardHook::Decode { shard: s, .. }
            | ShardHook::Leave { shard: s, .. }
            | ShardHook::Pause { shard: s }
            | ShardHook::Resume { shard: s, .. } => *s = shard,
        }
        h
    }
}

/// Per-sequence per-shard dense KV mirror: token `t` of shard `s`
/// occupies `k[s][t*w..(t+1)*w]` where `w` is the lane's slice width.
struct SeqMirror {
    len: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

/// A compute backend split across `M` simulated tensor-parallel lanes.
/// See the module docs for the partition, the collectives, and the
/// invisibility invariant.
pub struct ShardedBackend<B: Backend> {
    inner: B,
    shards: usize,
    budget: ShardBudget,
    /// Token-column element count, latched from the first KV-bearing
    /// hook (fills the per-lane `elems_lo/hi` ranges).
    te: Option<usize>,
    /// Per-shard dense mirrors of every batched sequence. Entries for
    /// sequences the core retires without a backend hook (a paused
    /// victim of admission relief gets no `on_batch_leave`) are pruned
    /// lazily at the next KV-bearing hook.
    mirrors: BTreeMap<SeqId, SeqMirror>,
    metrics: ShardMetrics,
    /// Opt-in per-lane hook trace, interior-mutable so integration
    /// tests can arm and drain it through the core's read-only
    /// [`crate::core::EngineCore::backend`] accessor.
    hook_trace: RefCell<Option<Vec<ShardHook>>>,
}

impl<B: Backend> ShardedBackend<B> {
    /// Wrap `inner` across `shards` lanes under the default
    /// [`ShardBudget`]. Panics if `shards == 0`.
    pub fn new(inner: B, shards: usize) -> Self {
        Self::with_budget(inner, shards, ShardBudget::default())
    }

    /// Like [`ShardedBackend::new`] with an explicit budget.
    pub fn with_budget(inner: B, shards: usize, budget: ShardBudget) -> Self {
        assert!(shards >= 1, "a sharded backend needs at least one lane");
        ShardedBackend {
            inner,
            shards,
            budget,
            te: None,
            mirrors: BTreeMap::new(),
            metrics: ShardMetrics::new(shards),
            hook_trace: RefCell::new(None),
        }
    }

    /// The lane count `M`.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Collective/budget accounting so far.
    pub fn shard_metrics(&self) -> &ShardMetrics {
        &self.metrics
    }

    /// [`ShardMetrics::to_json`] of the raw counters. Between
    /// KV-bearing hooks the per-lane `kv_elems` gauges may transiently
    /// include mirrors awaiting lazy pruning (a parked preemption
    /// victim gets no `on_batch_leave`); scrape paths should prefer
    /// [`ShardedBackend::stats_json_with_kv`], which reports post-GC
    /// values.
    pub fn stats_json(&self) -> Json {
        self.metrics.to_json()
    }

    /// [`ShardMetrics::to_json`] with the per-lane KV gauges reduced
    /// to their post-GC values: mirrors whose sequence already left
    /// the paged store are excluded, so `fdpp_shard_kv_elems` never
    /// over-reports after a preemption burst just because no
    /// KV-bearing hook has run since to prune them.
    pub fn stats_json_with_kv(&self, kv: &KvCache) -> Json {
        let mut m = self.metrics.clone();
        for (&id, mirror) in &self.mirrors {
            if kv.contains(id) {
                continue;
            }
            for (s, ks) in mirror.k.iter().enumerate() {
                let lane = &mut m.per_shard[s];
                lane.kv_elems = lane.kv_elems.saturating_sub(ks.len() as u64);
            }
        }
        m.to_json()
    }

    /// Whether `id` currently has a per-shard mirror (every batched or
    /// parked sequence must; `tests/prop_shard.rs` asserts it).
    pub fn is_mirrored(&self, id: SeqId) -> bool {
        self.mirrors.contains_key(&id)
    }

    /// Start recording per-lane hook events (drained with
    /// [`ShardedBackend::take_hook_trace`]).
    pub fn enable_hook_trace(&self) {
        *self.hook_trace.borrow_mut() = Some(Vec::new());
    }

    /// Drain the recorded hook events (empty when tracing is off).
    pub fn take_hook_trace(&self) -> Vec<ShardHook> {
        self.hook_trace
            .borrow_mut()
            .as_mut()
            .map(std::mem::take)
            .unwrap_or_default()
    }

    /// Reconstruct every mirrored sequence's dense state by
    /// concatenating its per-shard slices and compare element-exact
    /// against the paged store. Mirrors whose sequence already left the
    /// store (retired without a backend hook, awaiting lazy pruning)
    /// are skipped; present ones must match byte for byte.
    pub fn verify_sharding(&self, kv: &KvCache) -> std::result::Result<(), String> {
        let te = kv.geometry().token_elems();
        let mut kcol = vec![0.0f32; te];
        let mut vcol = vec![0.0f32; te];
        for (&id, m) in &self.mirrors {
            let Some(len) = kv.seq_len(id) else {
                continue;
            };
            if m.len != len {
                return Err(format!(
                    "seq {id}: mirror holds {} tokens but the store holds {len}",
                    m.len
                ));
            }
            for pos in 0..len {
                kv.read_token(id, pos, &mut kcol, &mut vcol)
                    .map_err(|e| format!("seq {id} pos {pos}: {e}"))?;
                for s in 0..self.shards {
                    let (lo, hi) = slice_range(te, self.shards, s);
                    let w = hi - lo;
                    if m.k[s][pos * w..(pos + 1) * w] != kcol[lo..hi] {
                        return Err(format!(
                            "seq {id} pos {pos} shard {s}: K slice diverged from the store"
                        ));
                    }
                    if m.v[s][pos * w..(pos + 1) * w] != vcol[lo..hi] {
                        return Err(format!(
                            "seq {id} pos {pos} shard {s}: V slice diverged from the store"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Latch the token-column width and fill the per-lane element
    /// ranges on first contact with a KV geometry.
    fn ensure_ranges(&mut self, te: usize) {
        if self.te == Some(te) {
            return;
        }
        self.te = Some(te);
        for s in 0..self.shards {
            let (lo, hi) = slice_range(te, self.shards, s);
            self.metrics.per_shard[s].elems_lo = lo as u64;
            self.metrics.per_shard[s].elems_hi = hi as u64;
        }
    }

    /// Record one hook as `M` per-lane events, shards ascending.
    fn record(&self, mk: impl Fn(usize) -> ShardHook) {
        if let Some(t) = self.hook_trace.borrow_mut().as_mut() {
            for s in 0..self.shards {
                t.push(mk(s));
            }
        }
    }

    /// Drop `id`'s mirror, releasing its per-lane footprint.
    fn drop_mirror(&mut self, id: SeqId) {
        if let Some(m) = self.mirrors.remove(&id) {
            for (s, ks) in m.k.iter().enumerate() {
                let lane = &mut self.metrics.per_shard[s];
                lane.kv_elems = lane.kv_elems.saturating_sub(ks.len() as u64);
            }
        }
    }

    /// Drop mirrors whose sequence no longer holds KV (retired through
    /// a core path with no backend hook, e.g. a parked preemption
    /// victim).
    fn prune_mirrors(&mut self, kv: &KvCache) {
        let stale: Vec<SeqId> = self
            .mirrors
            .keys()
            .copied()
            .filter(|&id| !kv.contains(id))
            .collect();
        for id in stale {
            self.drop_mirror(id);
        }
    }

    /// (Re)build `id`'s mirror from the paged store.
    fn rebuild_mirror(&mut self, kv: &KvCache, id: SeqId) -> Result<()> {
        let len = kv
            .seq_len(id)
            .ok_or_else(|| Error::KvCache(format!("mirror rebuild: unknown seq {id}")))?;
        let te = kv.geometry().token_elems();
        self.ensure_ranges(te);
        let shards = self.shards;
        let mut m = SeqMirror {
            len: 0,
            k: vec![Vec::new(); shards],
            v: vec![Vec::new(); shards],
        };
        let mut kcol = vec![0.0f32; te];
        let mut vcol = vec![0.0f32; te];
        for pos in 0..len {
            kv.read_token(id, pos, &mut kcol, &mut vcol)?;
            for s in 0..shards {
                let (lo, hi) = slice_range(te, shards, s);
                m.k[s].extend_from_slice(&kcol[lo..hi]);
                m.v[s].extend_from_slice(&vcol[lo..hi]);
            }
            m.len += 1;
        }
        self.drop_mirror(id);
        for s in 0..shards {
            let (lo, hi) = slice_range(te, shards, s);
            self.metrics.per_shard[s].kv_elems += (len * (hi - lo)) as u64;
        }
        self.mirrors.insert(id, m);
        Ok(())
    }

    /// Append the token the inner backend just wrote at `pos` to `id`'s
    /// mirror; falls back to a full rebuild if the mirror is missing or
    /// out of sync (defensive — never expected on the sim paths).
    fn append_mirror_token(&mut self, kv: &KvCache, id: SeqId, pos: usize) -> Result<()> {
        let in_sync = self.mirrors.get(&id).map(|m| m.len == pos).unwrap_or(false);
        if !in_sync {
            return self.rebuild_mirror(kv, id);
        }
        let te = kv.geometry().token_elems();
        let mut kcol = vec![0.0f32; te];
        let mut vcol = vec![0.0f32; te];
        kv.read_token(id, pos, &mut kcol, &mut vcol)?;
        let shards = self.shards;
        let mirror = self.mirrors.get_mut(&id).expect("mirror checked in sync");
        for s in 0..shards {
            let (lo, hi) = slice_range(te, shards, s);
            mirror.k[s].extend_from_slice(&kcol[lo..hi]);
            mirror.v[s].extend_from_slice(&vcol[lo..hi]);
            self.metrics.per_shard[s].kv_elems += (hi - lo) as u64;
        }
        mirror.len += 1;
        Ok(())
    }

    /// Collective accounting for `rows` result rows: all-gather of the
    /// attention outputs, ring all-reduce of the logits partials.
    /// Returns the modeled link time; `M = 1` moves nothing.
    fn collectives(&mut self, te: usize, vocab: usize, rows: u64) -> f64 {
        let m = self.shards as u64;
        if m <= 1 {
            return 0.0;
        }
        let ag_bytes = rows * (m - 1) * te as u64 * 4;
        let ar_bytes = rows * 2 * (m - 1) * vocab as u64 * 4;
        self.metrics.allgather_ops += rows;
        self.metrics.allgather_bytes += ag_bytes;
        self.metrics.allreduce_ops += rows;
        self.metrics.allreduce_bytes += ar_bytes;
        (ag_bytes + ar_bytes) as f64 / self.budget.link_bw
            + 2.0 * (m - 1) as f64 * self.budget.link_latency_s
    }

    /// Budget a successful prefill call (one result row).
    fn account_prefill(&mut self, geo: &KvGeometry, vocab: usize, prompt_len: usize) {
        let m = self.shards as f64;
        self.metrics.prefills += 1;
        let attn = attention_prefill_time(
            &self.budget.gpu,
            1,
            geo.n_heads,
            geo.head_dim,
            prompt_len.max(1),
            false,
            2,
        ) * geo.n_layers as f64;
        let gemm = gemm_time(
            &self.budget.gpu,
            ImplKind::B,
            1,
            vocab.div_ceil(self.shards),
            geo.n_heads * geo.head_dim,
            2,
        );
        let comp = attn / m + gemm;
        let sync = self.collectives(geo.token_elems(), vocab, 1);
        self.metrics.compute_s += comp;
        self.metrics.collective_s += sync;
    }

    /// Budget a successful decode call over `inputs`.
    fn account_decode(&mut self, geo: &KvGeometry, vocab: usize, inputs: &[LaneInput]) {
        let rows = inputs.len();
        if rows == 0 {
            return;
        }
        let kv_len = inputs.iter().map(|i| i.pos + 1).max().unwrap_or(1);
        let m = self.shards as f64;
        self.metrics.decode_calls += 1;
        self.metrics.decode_rows += rows as u64;
        for s in 0..self.shards {
            self.metrics.per_shard[s].decode_rows += rows as u64;
        }
        let attn = attention_decode_time(
            &self.budget.gpu,
            rows,
            geo.n_heads,
            geo.head_dim,
            kv_len,
            SoftmaxScheme::AsyncUnified,
            2,
        ) * geo.n_layers as f64;
        let gemm = gemm_time(
            &self.budget.gpu,
            ImplKind::B,
            rows,
            vocab.div_ceil(self.shards),
            geo.n_heads * geo.head_dim,
            2,
        );
        let comp = attn / m + gemm;
        let sync = self.collectives(geo.token_elems(), vocab, rows as u64);
        self.metrics.compute_s += comp;
        self.metrics.collective_s += sync;
        self.metrics.decode_compute_s += comp;
        self.metrics.decode_collective_s += sync;
    }
}

impl<B: Backend> Backend for ShardedBackend<B> {
    type PrefillArtifact = B::PrefillArtifact;

    fn geometry(&self, cfg: &EngineConfig) -> KvGeometry {
        self.inner.geometry(cfg)
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn validate_prompt(&self, cfg: &EngineConfig, prompt_len: usize) -> Result<()> {
        self.inner.validate_prompt(cfg, prompt_len)
    }

    fn on_step_start(&mut self, clock: &Clock) {
        self.inner.on_step_start(clock);
    }

    fn prefill(
        &mut self,
        cfg: &EngineConfig,
        kv: &mut KvCache,
        seq: &Sequence,
        matched_tokens: usize,
        clock: &Clock,
    ) -> Result<PrefillRun<B::PrefillArtifact>> {
        self.prune_mirrors(kv);
        let run = self.inner.prefill(cfg, kv, seq, matched_tokens, clock)?;
        let geo = kv.geometry();
        self.ensure_ranges(geo.token_elems());
        let vocab = self.inner.vocab();
        self.account_prefill(&geo, vocab, seq.prompt.len());
        self.record(|s| ShardHook::Prefill {
            shard: s,
            id: seq.id,
        });
        Ok(run)
    }

    fn on_batch_join(
        &mut self,
        kv: &mut KvCache,
        metrics: &mut EngineMetrics,
        id: SeqId,
        admission: Admission,
        artifact: Self::PrefillArtifact,
        clock: &Clock,
    ) -> Result<Duration> {
        let lane = admission.lane;
        let d = self
            .inner
            .on_batch_join(kv, metrics, id, admission, artifact, clock)?;
        self.prune_mirrors(kv);
        self.rebuild_mirror(kv, id)?;
        for s in 0..self.shards {
            self.metrics.per_shard[s].joins += 1;
        }
        self.record(|s| ShardHook::Join { shard: s, id, lane });
        Ok(d)
    }

    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        cfg: &EngineConfig,
        kv: &mut KvCache,
        seqs: &HashMap<SeqId, Sequence>,
        batch: &DecodeBatch,
        inputs: &[LaneInput],
        metrics: &mut EngineMetrics,
        clock: &Clock,
    ) -> Result<DecodeRun> {
        self.prune_mirrors(kv);
        let run = self
            .inner
            .decode(cfg, kv, seqs, batch, inputs, metrics, clock)?;
        for inp in inputs {
            self.append_mirror_token(kv, inp.id, inp.pos)?;
        }
        let geo = kv.geometry();
        self.ensure_ranges(geo.token_elems());
        let vocab = self.inner.vocab();
        self.account_decode(&geo, vocab, inputs);
        self.record(|s| ShardHook::Decode {
            shard: s,
            rows: inputs.len(),
        });
        Ok(run)
    }

    fn on_batch_leave(&mut self, kv: &mut KvCache, id: SeqId, shrank: bool) -> Result<()> {
        self.inner.on_batch_leave(kv, id, shrank)?;
        self.drop_mirror(id);
        for s in 0..self.shards {
            self.metrics.per_shard[s].leaves += 1;
        }
        self.record(|s| ShardHook::Leave {
            shard: s,
            id,
            shrank,
        });
        Ok(())
    }

    fn on_pause(&mut self, kv: &mut KvCache) -> Result<()> {
        self.inner.on_pause(kv)?;
        for s in 0..self.shards {
            self.metrics.per_shard[s].pauses += 1;
        }
        self.record(|s| ShardHook::Pause { shard: s });
        Ok(())
    }

    fn on_resume(&mut self, kv: &mut KvCache, admission: &Admission) -> Result<()> {
        self.inner.on_resume(kv, admission)?;
        for s in 0..self.shards {
            self.metrics.per_shard[s].resumes += 1;
        }
        let lane = admission.lane;
        self.record(|s| ShardHook::Resume { shard: s, lane });
        Ok(())
    }

    fn publishable_tokens(&self, kv: &KvCache, seq: &Sequence) -> Vec<u32> {
        self.inner.publishable_tokens(kv, seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{GenRequest, InferenceEngine};
    use crate::core::EngineCore;
    use crate::sampling::SamplingParams;
    use crate::simengine::{SimBackend, SimEngine, SimSpec};

    fn cfg() -> EngineConfig {
        EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 16,
            prefix_cache: true,
            ..EngineConfig::default()
        }
    }

    fn sharded(m: usize) -> EngineCore<ShardedBackend<SimBackend>> {
        EngineCore::with_backend(
            ShardedBackend::new(SimBackend::new(SimSpec::default()), m),
            cfg(),
            Clock::manual(),
        )
        .unwrap()
    }

    #[test]
    fn slice_ranges_tile_the_column_exactly() {
        for te in [1usize, 5, 16, 33, 64] {
            for m in 1..=9usize {
                let mut covered = 0;
                for s in 0..m {
                    let (lo, hi) = slice_range(te, m, s);
                    assert!(lo <= hi);
                    if s > 0 {
                        assert_eq!(lo, slice_range(te, m, s - 1).1, "te={te} m={m} s={s}");
                    }
                    covered += hi - lo;
                }
                assert_eq!(covered, te, "te={te} m={m}");
                assert_eq!(slice_range(te, m, 0).0, 0);
                assert_eq!(slice_range(te, m, m - 1).1, te);
            }
        }
    }

    #[test]
    fn m1_is_transparent_and_runs_no_collectives() {
        let mut a = sharded(1);
        let mut b = SimEngine::new(cfg(), SimSpec::default()).unwrap();
        let ta = a
            .generate_text("shard transparency probe", 12, SamplingParams::default())
            .unwrap();
        let tb = b
            .generate_text("shard transparency probe", 12, SamplingParams::default())
            .unwrap();
        assert_eq!(ta, tb, "M=1 must be bit-transparent");
        assert_eq!(a.metrics.tokens_generated, b.metrics.tokens_generated);
        let sm = a.backend().shard_metrics();
        assert_eq!(sm.allgather_ops, 0, "M=1 runs no collectives");
        assert_eq!(sm.allreduce_bytes, 0);
        assert_eq!(sm.collective_s, 0.0);
        assert!(sm.compute_s > 0.0, "budget accounting still runs");
    }

    #[test]
    fn collectives_match_the_analytic_formula() {
        let mut e = sharded(4);
        for p in ["alpha", "beta prompt", "gamma gamma gamma"] {
            e.submit(GenRequest::text(p).max_new_tokens(10)).unwrap();
        }
        e.run_to_completion().unwrap();
        e.backend().verify_sharding(e.kv()).unwrap();
        let sm = e.backend().shard_metrics();
        let expected = e.metrics.prefill_steps + e.metrics.decode_rows;
        assert!(expected > 0);
        assert_eq!(sm.allgather_ops, expected);
        assert_eq!(sm.allreduce_ops, expected);
        let te = e.geometry().token_elems() as u64;
        let vocab = SimSpec::default().vocab as u64;
        assert_eq!(sm.allgather_bytes, expected * 3 * te * 4);
        assert_eq!(sm.allreduce_bytes, expected * 2 * 3 * vocab * 4);
        assert!(sm.decode_collective_s > 0.0);
        assert!(
            e.backend().mirrors.is_empty(),
            "every retired sequence must release its mirror"
        );
    }

    #[test]
    fn verify_sharding_catches_a_corrupted_slice() {
        let mut e = sharded(2);
        e.submit(GenRequest::text("corruption probe prompt").max_new_tokens(12))
            .unwrap();
        for _ in 0..4 {
            e.step().unwrap();
        }
        assert!(
            !e.backend.mirrors.is_empty(),
            "a decoding sequence must be mirrored"
        );
        e.backend().verify_sharding(e.kv()).unwrap();
        {
            let m = e.backend.mirrors.values_mut().next().unwrap();
            m.k[1][0] += 0.5;
        }
        assert!(
            e.backend().verify_sharding(e.kv()).is_err(),
            "a flipped element must fail reconstruction"
        );
    }

    #[test]
    fn hook_trace_groups_cover_lanes_in_order() {
        let mut e = sharded(3);
        e.backend().enable_hook_trace();
        for p in ["hook order alpha", "hook order beta"] {
            e.submit(GenRequest::text(p).max_new_tokens(6)).unwrap();
        }
        e.run_to_completion().unwrap();
        let hooks = e.backend().take_hook_trace();
        assert!(!hooks.is_empty());
        assert_eq!(hooks.len() % 3, 0, "events come in whole per-lane groups");
        let mut i = 0;
        while i < hooks.len() {
            for s in 0..3 {
                assert_eq!(
                    hooks[i + s],
                    hooks[i].at_shard(s),
                    "group at {i} must replicate one hook across lanes in order"
                );
            }
            i += 3;
        }
        let saw_join = hooks.iter().any(|h| matches!(h, ShardHook::Join { .. }));
        let saw_leave = hooks.iter().any(|h| matches!(h, ShardHook::Leave { .. }));
        assert!(saw_join, "joins recorded");
        assert!(saw_leave, "leaves recorded");
    }

    #[test]
    fn kv_gauges_report_post_gc_values_with_stale_mirrors() {
        let mut e = sharded(2);
        e.submit(GenRequest::text("gauge probe prompt").max_new_tokens(12))
            .unwrap();
        for _ in 0..4 {
            e.step().unwrap();
        }
        let live: Vec<u64> = e
            .backend()
            .shard_metrics()
            .per_shard
            .iter()
            .map(|l| l.kv_elems)
            .collect();
        assert!(live.iter().all(|&n| n > 0), "a decoding seq is mirrored");
        // Fabricate what a parked preemption victim leaves behind: a
        // mirror whose sequence no longer holds KV, awaiting lazy
        // pruning at the next KV-bearing hook.
        let te = e.geometry().token_elems();
        let ghost: SeqId = u64::MAX;
        assert!(!e.kv().contains(ghost));
        let mut m = SeqMirror {
            len: 3,
            k: vec![Vec::new(); 2],
            v: vec![Vec::new(); 2],
        };
        for s in 0..2 {
            let (lo, hi) = slice_range(te, 2, s);
            m.k[s] = vec![0.25; 3 * (hi - lo)];
            m.v[s] = vec![0.5; 3 * (hi - lo)];
            e.backend.metrics.per_shard[s].kv_elems += (3 * (hi - lo)) as u64;
        }
        e.backend.mirrors.insert(ghost, m);
        let raw = e.backend().stats_json();
        let post = e.backend().stats_json_with_kv(e.kv());
        for s in 0..2usize {
            let key = s.to_string();
            let elems = |j: &Json| {
                j.get("per_shard")
                    .and_then(|p| p.get(&key))
                    .and_then(|l| l.get("kv_elems"))
                    .and_then(Json::as_f64)
                    .unwrap()
            };
            let (lo, hi) = slice_range(te, 2, s);
            assert_eq!(
                elems(&raw),
                (live[s] + (3 * (hi - lo)) as u64) as f64,
                "raw gauge over-reports by the ghost footprint (lane {s})"
            );
            assert_eq!(
                elems(&post),
                live[s] as f64,
                "post-GC gauge excludes the stale mirror (lane {s})"
            );
        }
        // The next KV-bearing hook prunes the ghost for real; the two
        // snapshots agree again.
        e.step().unwrap();
        assert!(!e.backend().is_mirrored(ghost));
        assert_eq!(
            e.backend().stats_json().to_string(),
            e.backend().stats_json_with_kv(e.kv()).to_string()
        );
    }

    #[test]
    fn grouped_decode_flag_is_invisible_through_the_default_delegation() {
        // The wrapper does not override `decode_grouped`, so the trait
        // default routes grouped steps through the per-sequence decode
        // path: a sharded engine with grouping enabled must stay
        // byte-identical to the unsharded ungrouped baseline — groups
        // are surfaced by the core, ignored by the backend, and no
        // savings may be claimed.
        fn wave<E: InferenceEngine>(e: &mut E, shared: &str) -> Vec<Vec<u32>> {
            let w = e.submit(GenRequest::text(shared).max_new_tokens(2)).unwrap();
            e.run_to_completion().unwrap();
            let _ = w.drain();
            let handles: Vec<_> = (0..4)
                .map(|i| {
                    e.submit(GenRequest::text(format!("{shared} user {i}")).max_new_tokens(8))
                        .unwrap()
                })
                .collect();
            e.run_to_completion().unwrap();
            handles.iter().map(|h| h.drain().0).collect()
        }
        let shared = "system: you are a helpful tool!!"; // 4 full blocks with BOS
        let mut base = SimEngine::new(cfg(), SimSpec::default()).unwrap();
        let expect = wave(&mut base, shared);
        for m in [2usize, 3] {
            let mut e = EngineCore::with_backend(
                ShardedBackend::new(SimBackend::new(SimSpec::default()), m),
                EngineConfig {
                    grouped_decode: true,
                    ..cfg()
                },
                Clock::manual(),
            )
            .unwrap();
            let got = wave(&mut e, shared);
            assert_eq!(expect, got, "M={m} grouped must match the baseline");
            assert!(
                e.metrics.grouped_groups_formed > 0,
                "the core must still surface groups (M={m})"
            );
            assert_eq!(
                e.metrics.decode_attn_positions_saved,
                0,
                "the default delegation claims no reuse (M={m})"
            );
            e.backend().verify_sharding(e.kv()).unwrap();
        }
    }

    #[test]
    fn shard_metrics_json_carries_per_lane_gauges() {
        let mut e = sharded(2);
        e.submit(GenRequest::text("json probe").max_new_tokens(4))
            .unwrap();
        e.run_to_completion().unwrap();
        let j = e.backend().stats_json();
        assert_eq!(j.get("shard_count").and_then(Json::as_f64), Some(2.0));
        for key in ["allgather_ops", "allreduce_bytes", "decode_compute_ms"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let lane0 = j.get("per_shard").and_then(|p| p.get("0")).unwrap();
        assert!(lane0.get("joins").and_then(Json::as_f64).unwrap() >= 1.0);
        let lo = lane0.get("elems_lo").and_then(Json::as_f64).unwrap();
        let hi = lane0.get("elems_hi").and_then(Json::as_f64).unwrap();
        assert_eq!(lo, 0.0);
        assert_eq!(hi, 8.0, "16 elements over 2 lanes");
    }
}
