//! The public serving API: typed requests, streamed events, and the
//! [`InferenceEngine`] trait implemented by both the PJRT-backed
//! [`crate::engine::Engine`] and the deterministic
//! [`crate::simengine::SimEngine`] twin.
//!
//! One abstraction serves every front-end: the JSON-lines TCP server,
//! the benches, the property tests, and the offline batch drivers all
//! drive a generic `InferenceEngine`, so the sim twin cannot drift from
//! the real engine's surface. The scheduling *policy* shared by both
//! implementations lives in [`crate::policy`]; this module owns the
//! request/response model:
//!
//! - [`GenRequest`]: client id, tenant, priority, stop sequences,
//!   sampling params, token budget (builder-style constructors).
//! - [`SubmissionHandle`]: the engine-assigned [`RequestId`] plus the
//!   [`GenEvent`] stream for that request.
//! - [`GenEvent`]: streamed tokens, then exactly one `Finished`
//!   carrying the [`FinishReason`] and a per-request [`Usage`] record
//!   (prefill / cached / generated token counts).

use std::sync::mpsc;

use crate::error::Result;
use crate::metrics::EngineMetrics;
use crate::sampling::SamplingParams;
use crate::scheduler::Action;

/// Engine-assigned request identifier (monotone per engine; doubles as
/// the KV-cache sequence id).
pub type RequestId = u64;

/// What the client wants generated.
#[derive(Debug, Clone, PartialEq)]
pub enum Prompt {
    /// Raw text, encoded by the engine's tokenizer at submit time.
    Text(String),
    /// Pre-tokenized ids (must be non-empty).
    Tokens(Vec<u32>),
}

/// A typed generation request — the only submission surface.
#[derive(Debug, Clone, PartialEq)]
pub struct GenRequest {
    /// Optional client correlation id: front-ends (the JSON-lines
    /// server, docs/PROTOCOL.md) tag every response for this request
    /// with it. Engines never interpret it — they identify requests by
    /// the [`RequestId`] they assign at submit.
    pub client_id: Option<String>,
    pub prompt: Prompt,
    /// Multi-tenant accounting key; empty means `"default"`.
    pub tenant: String,
    /// Admission priority: higher is admitted first, FIFO within a
    /// level.
    pub priority: i32,
    /// Generation finishes with [`FinishReason::Stop`] when the
    /// generated token stream ends with the encoding of any of these
    /// strings.
    pub stop: Vec<String>,
    pub params: SamplingParams,
    /// Requested budget; engines clamp it to their configured cap.
    pub max_new_tokens: usize,
}

impl GenRequest {
    /// A request for a text prompt, with default fields.
    pub fn text(prompt: impl Into<String>) -> Self {
        GenRequest::new(Prompt::Text(prompt.into()))
    }

    /// A request for a pre-tokenized prompt, with default fields.
    pub fn tokens(prompt_tokens: Vec<u32>) -> Self {
        GenRequest::new(Prompt::Tokens(prompt_tokens))
    }

    fn new(prompt: Prompt) -> Self {
        GenRequest {
            client_id: None,
            prompt,
            tenant: String::new(),
            priority: 0,
            stop: Vec::new(),
            params: SamplingParams::default(),
            max_new_tokens: usize::MAX,
        }
    }

    pub fn client_id(mut self, id: impl Into<String>) -> Self {
        self.client_id = Some(id.into());
        self
    }

    pub fn tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = tenant.into();
        self
    }

    pub fn priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    pub fn stop(mut self, stop: Vec<String>) -> Self {
        self.stop = stop;
        self
    }

    pub fn params(mut self, params: SamplingParams) -> Self {
        self.params = params;
        self
    }

    pub fn max_new_tokens(mut self, max_new_tokens: usize) -> Self {
        self.max_new_tokens = max_new_tokens;
        self
    }
}

/// Why a request stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    /// A client stop sequence matched the generated tail.
    Stop,
    /// Cancelled via [`InferenceEngine::cancel`].
    Cancelled,
    /// KV capacity forced us to stop early.
    Preempted,
    Error,
}

impl FinishReason {
    /// Stable wire-protocol name (docs/PROTOCOL.md).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Preempted => "preempted",
            FinishReason::Error => "error",
        }
    }
}

/// Per-request token accounting, reported with the final [`GenEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Usage {
    /// Prompt length in tokens (cached + prefilled).
    pub prompt_tokens: usize,
    /// Prompt tokens served from the prefix cache (no prefill compute).
    pub cached_prompt_tokens: usize,
    /// Prompt tokens that went through prefill compute.
    pub prefill_tokens: usize,
    pub generated_tokens: usize,
}

/// Streamed events a client receives for one request.
#[derive(Debug, Clone)]
pub enum GenEvent {
    Token(u32),
    Finished { reason: FinishReason, usage: Usage },
}

/// What [`InferenceEngine::submit`] hands back: the assigned id (usable
/// with `cancel`) and the per-request event stream.
#[derive(Debug)]
pub struct SubmissionHandle {
    pub id: RequestId,
    pub events: mpsc::Receiver<GenEvent>,
}

impl SubmissionHandle {
    /// Drain every buffered event: generated tokens plus, once the
    /// request is over, its finish reason and usage record.
    pub fn drain(&self) -> (Vec<u32>, Option<(FinishReason, Usage)>) {
        let mut toks = Vec::new();
        let mut fin = None;
        while let Ok(ev) = self.events.try_recv() {
            match ev {
                GenEvent::Token(t) => toks.push(t),
                GenEvent::Finished { reason, usage } => fin = Some((reason, usage)),
            }
        }
        (toks, fin)
    }
}

/// The serving-engine abstraction. [`crate::engine::Engine`] (PJRT) and
/// [`crate::simengine::SimEngine`] (deterministic hash model) both
/// implement it over the same router / scheduler / KV-cache / policy
/// stack, so anything written against this trait — server, benches,
/// property tests — runs unchanged on either.
pub trait InferenceEngine {
    /// Queue a request; returns the assigned id and event stream.
    fn submit(&mut self, req: GenRequest) -> Result<SubmissionHandle>;

    /// Run one scheduling iteration (prefill, decode, or idle).
    fn step(&mut self) -> Result<Action>;

    /// Cancel a queued or running request: its stream receives one
    /// final `Finished { reason: Cancelled, .. }` and every KV block it
    /// held is released. Returns `false` for unknown (or already
    /// finished) ids.
    fn cancel(&mut self, id: RequestId) -> Result<bool>;

    /// Cumulative engine metrics (counters, latency histograms,
    /// per-tenant usage).
    fn metrics(&self) -> &EngineMetrics;

    /// True when no work remains (queue empty, nothing running).
    fn is_idle(&self) -> bool;

    fn queued(&self) -> usize;

    fn running(&self) -> usize;

    /// Tokenize prompt text exactly the way `submit` would.
    fn encode(&self, text: &str) -> Vec<u32>;

    /// Decode generated ids to text.
    fn decode(&self, tokens: &[u32]) -> String;

    /// Drive until all submitted work is finished (offline mode).
    fn run_to_completion(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Offline helper: one blocking generation, decoded to text.
    fn generate_text(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<String> {
        let req = GenRequest::text(prompt)
            .params(params)
            .max_new_tokens(max_new_tokens);
        let handle = self.submit(req)?;
        self.run_to_completion()?;
        let (toks, _) = handle.drain();
        Ok(self.decode(&toks))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_fields() {
        let r = GenRequest::text("hi")
            .client_id("abc")
            .tenant("acme")
            .priority(3)
            .stop(vec!["\n".into()])
            .max_new_tokens(7);
        assert_eq!(r.client_id.as_deref(), Some("abc"));
        assert_eq!(r.tenant, "acme");
        assert_eq!(r.priority, 3);
        assert_eq!(r.stop, vec!["\n".to_string()]);
        assert_eq!(r.max_new_tokens, 7);
        assert_eq!(r.prompt, Prompt::Text("hi".into()));
    }

    #[test]
    fn finish_reason_wire_names_are_stable() {
        for (r, s) in [
            (FinishReason::Eos, "eos"),
            (FinishReason::MaxTokens, "max_tokens"),
            (FinishReason::Stop, "stop"),
            (FinishReason::Cancelled, "cancelled"),
            (FinishReason::Preempted, "preempted"),
            (FinishReason::Error, "error"),
        ] {
            assert_eq!(r.as_str(), s);
        }
    }

    #[test]
    fn drain_collects_tokens_and_finish() {
        let (tx, rx) = mpsc::channel();
        let h = SubmissionHandle { id: 1, events: rx };
        tx.send(GenEvent::Token(10)).unwrap();
        tx.send(GenEvent::Token(11)).unwrap();
        tx.send(GenEvent::Finished {
            reason: FinishReason::Eos,
            usage: Usage {
                prompt_tokens: 4,
                cached_prompt_tokens: 0,
                prefill_tokens: 4,
                generated_tokens: 2,
            },
        })
        .unwrap();
        let (toks, fin) = h.drain();
        assert_eq!(toks, vec![10, 11]);
        let (reason, usage) = fin.unwrap();
        assert_eq!(reason, FinishReason::Eos);
        assert_eq!(usage.generated_tokens, 2);
    }
}
