//! The public serving API: typed requests, bounded streamed events, and
//! the [`InferenceEngine`] trait implemented by both the PJRT-backed
//! [`crate::engine::Engine`] and the deterministic
//! [`crate::simengine::SimEngine`] twin.
//!
//! One abstraction serves every front-end: the JSON-lines TCP server,
//! the benches, the property tests, and the offline batch drivers all
//! drive a generic `InferenceEngine`, so the sim twin cannot drift from
//! the real engine's surface. The scheduling *policy* shared by both
//! implementations lives in [`crate::policy`]; this module owns the
//! request/response model:
//!
//! - [`GenRequest`]: client id, tenant, priority, stop sequences,
//!   sampling params, token budget (builder-style constructors).
//! - [`SubmissionHandle`]: the engine-assigned [`RequestId`] plus the
//!   bounded [`GenEvent`] stream for that request.
//! - [`GenEvent`]: streamed tokens, then exactly one `Finished`
//!   carrying the [`FinishReason`] and a per-request [`Usage`] record
//!   (prefill / cached / generated token counts).
//!
//! # Bounded event streams (flow control)
//!
//! Event streams are credit-based, not unbounded queues: each stream
//! created by [`event_channel`] holds at most `capacity` undelivered
//! tokens (the [`crate::config::EngineConfig::stream_capacity`] knob).
//! The engine never blocks on a slow client — [`EventSender::try_token`]
//! fails with [`EmitResult::Full`] and the engine applies its configured
//! [`crate::config::BackpressurePolicy`] (pause the sequence's decode,
//! or finish it with [`FinishReason::Overrun`]). The terminal `Finished`
//! event lives in a dedicated slot outside the token budget, so a
//! request's outcome is always deliverable even when its token buffer is
//! full. Engines check stream credit *before* decoding a sequence, so a
//! generated token is never dropped: generation halts instead.
//!
//! The full architecture (request lifecycle, backpressure state
//! machine) is documented in `docs/ARCHITECTURE.md`; the wire surface in
//! `docs/PROTOCOL.md`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::metrics::EngineMetrics;
use crate::obs::SpanBreakdown;
use crate::sampling::SamplingParams;
use crate::scheduler::Action;
use crate::util::json::Json;

// ---------------------------------------------------------------------
// Wakeup: drain-path notification for the engine loop
// ---------------------------------------------------------------------

/// Edge-triggered notification channel between client-side stream
/// drains and the engine thread.
///
/// When every live request is parked on backpressure, the engine loop
/// has nothing to do until some client drains its bounded stream (or
/// hangs up, or a new job arrives). It used to poll with a fixed nap;
/// now it blocks on a `Wakeup` that is notified from exactly those
/// three places, so resume latency is event-driven instead of
/// poll-quantized. The epoch counter closes the check-then-wait race:
/// capture [`Wakeup::epoch`] *before* inspecting engine state, then
/// [`Wakeup::wait_from`] returns immediately if anything notified in
/// between.
#[derive(Debug, Clone, Default)]
pub struct Wakeup {
    inner: Arc<WakeupInner>,
}

#[derive(Debug, Default)]
struct WakeupInner {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Wakeup {
    pub fn new() -> Self {
        Wakeup::default()
    }

    /// Current notification epoch; pass to [`Wakeup::wait_from`].
    pub fn epoch(&self) -> u64 {
        *self.inner.epoch.lock().unwrap()
    }

    /// Record one notification and wake every waiter.
    pub fn notify(&self) {
        let mut g = self.inner.epoch.lock().unwrap();
        *g = g.wrapping_add(1);
        drop(g);
        self.inner.cv.notify_all();
    }

    /// Block until the epoch advances past `seen` or `timeout` elapses
    /// (a safety net, not the expected wake path). Returns `true` when
    /// a notification arrived.
    pub fn wait_from(&self, seen: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.epoch.lock().unwrap();
        while *g == seen {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            let (guard, _) = self.inner.cv.wait_timeout(g, left).unwrap();
            g = guard;
        }
        true
    }
}

/// Engine-assigned request identifier (monotone per engine; doubles as
/// the KV-cache sequence id).
pub type RequestId = u64;

/// What the client wants generated.
#[derive(Debug, Clone, PartialEq)]
pub enum Prompt {
    /// Raw text, encoded by the engine's tokenizer at submit time.
    Text(String),
    /// Pre-tokenized ids (must be non-empty).
    Tokens(Vec<u32>),
}

/// A typed generation request — the only submission surface.
#[derive(Debug, Clone, PartialEq)]
pub struct GenRequest {
    /// Optional client correlation id: front-ends (the JSON-lines
    /// server, docs/PROTOCOL.md) tag every response for this request
    /// with it. Engines never interpret it — they identify requests by
    /// the [`RequestId`] they assign at submit.
    pub client_id: Option<String>,
    pub prompt: Prompt,
    /// Multi-tenant accounting key; empty means `"default"`.
    pub tenant: String,
    /// Admission priority: higher is admitted first, FIFO within a
    /// level. Preemption victims — drawn from running *and*
    /// backpressure-paused requests — are chosen lowest-priority-first,
    /// so a high-priority request is never preempted while a
    /// lower-priority victim exists.
    pub priority: i32,
    /// Generation finishes with [`FinishReason::Stop`] when the
    /// generated token stream ends with the encoding of any of these
    /// strings.
    pub stop: Vec<String>,
    pub params: SamplingParams,
    /// Requested budget; engines clamp it to their configured cap.
    pub max_new_tokens: usize,
}

impl GenRequest {
    /// A request for a text prompt, with default fields.
    pub fn text(prompt: impl Into<String>) -> Self {
        GenRequest::new(Prompt::Text(prompt.into()))
    }

    /// A request for a pre-tokenized prompt, with default fields.
    pub fn tokens(prompt_tokens: Vec<u32>) -> Self {
        GenRequest::new(Prompt::Tokens(prompt_tokens))
    }

    fn new(prompt: Prompt) -> Self {
        GenRequest {
            client_id: None,
            prompt,
            tenant: String::new(),
            priority: 0,
            stop: Vec::new(),
            params: SamplingParams::default(),
            max_new_tokens: usize::MAX,
        }
    }

    pub fn client_id(mut self, id: impl Into<String>) -> Self {
        self.client_id = Some(id.into());
        self
    }

    pub fn tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = tenant.into();
        self
    }

    pub fn priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    pub fn stop(mut self, stop: Vec<String>) -> Self {
        self.stop = stop;
        self
    }

    pub fn params(mut self, params: SamplingParams) -> Self {
        self.params = params;
        self
    }

    pub fn max_new_tokens(mut self, max_new_tokens: usize) -> Self {
        self.max_new_tokens = max_new_tokens;
        self
    }
}

/// Why a request stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    /// A client stop sequence matched the generated tail.
    Stop,
    /// Cancelled via [`InferenceEngine::cancel`], or the client went
    /// away (its event stream was dropped) and the engine reclaimed the
    /// request.
    Cancelled,
    /// KV capacity forced us to stop early.
    Preempted,
    /// The client consumed tokens slower than the engine produced them,
    /// its bounded stream filled, and the engine's backpressure policy
    /// is [`crate::config::BackpressurePolicy::DropSlow`]: the request
    /// is finished early and its KV reclaimed. Every token generated
    /// before the overrun is still in the stream buffer.
    Overrun,
    Error,
}

impl FinishReason {
    /// Stable wire-protocol name (docs/PROTOCOL.md).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Preempted => "preempted",
            FinishReason::Overrun => "overrun",
            FinishReason::Error => "error",
        }
    }
}

/// Per-request token accounting, reported with the final [`GenEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Usage {
    /// Prompt length in tokens (cached + prefilled).
    pub prompt_tokens: usize,
    /// Prompt tokens served from the prefix cache (no prefill compute).
    pub cached_prompt_tokens: usize,
    /// Prompt tokens that went through prefill compute.
    pub prefill_tokens: usize,
    pub generated_tokens: usize,
}

/// Streamed events a client receives for one request.
#[derive(Debug, Clone)]
pub enum GenEvent {
    Token(u32),
    Finished { reason: FinishReason, usage: Usage },
}

// ---------------------------------------------------------------------
// Bounded event stream
// ---------------------------------------------------------------------

/// Outcome of a non-blocking token emit ([`EventSender::try_token`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmitResult {
    Sent,
    /// The stream holds `capacity` undelivered tokens; the engine must
    /// apply its backpressure policy instead of generating more.
    Full,
    /// The receiver was dropped (client gone); the engine should
    /// reclaim the request.
    Closed,
}

/// Sender-side view of a stream's credit, sampled by the engines before
/// each decode step ([`EventSender::status`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamStatus {
    /// At least one token slot is free.
    Ready,
    /// No free token slots: the next emit would fail.
    Full,
    /// The receiver was dropped.
    Closed,
}

/// `try_recv` failure: nothing buffered right now, or the stream ended
/// (terminal event already delivered, or the sender is gone).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Closed,
}

/// Blocking `recv` failure: the stream ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

#[derive(Debug)]
struct StreamState {
    tokens: VecDeque<u32>,
    finished: Option<(FinishReason, Usage)>,
    finish_delivered: bool,
    tx_alive: bool,
    rx_alive: bool,
    /// Observability side channel: the request's lifecycle phase
    /// breakdown, stamped by the engine when it closes the span (see
    /// [`crate::obs`]). Rides next to the terminal event rather than in
    /// [`Usage`] so the typed event surface is unchanged.
    breakdown: Option<SpanBreakdown>,
}

#[derive(Debug)]
struct StreamShared {
    state: Mutex<StreamState>,
    readable: Condvar,
    capacity: usize,
    /// Notified when the receiver drains across the resume threshold
    /// (half capacity — the transition `policy::ready_to_resume` acts
    /// on) or goes away: the engine loop may be blocked waiting for
    /// exactly that.
    drain: Option<Wakeup>,
}

/// Engine-side endpoint of a bounded event stream. Held by the
/// sequence; every operation is non-blocking (the engine hot loop must
/// never wait on a client).
#[derive(Debug)]
pub struct EventSender {
    ch: Arc<StreamShared>,
}

/// Client-side endpoint of a bounded event stream; the `events` field
/// of a [`SubmissionHandle`]. Dropping it signals the engine that the
/// client is gone.
#[derive(Debug)]
pub struct EventReceiver {
    ch: Arc<StreamShared>,
}

/// Create a bounded event stream holding at most `capacity` undelivered
/// tokens (floored to 1). The terminal `Finished` event has its own
/// slot and is always deliverable.
pub fn event_channel(capacity: usize) -> (EventSender, EventReceiver) {
    event_channel_with_wakeup(capacity, None)
}

/// [`event_channel`] plus a drain-path [`Wakeup`]: the engine loop is
/// notified when the receiver drains across the resume threshold or is
/// dropped, so a parked sequence's resume is event-driven rather than
/// polled — without serializing every token pop on the shared wakeup.
pub fn event_channel_with_wakeup(
    capacity: usize,
    drain: Option<Wakeup>,
) -> (EventSender, EventReceiver) {
    let ch = Arc::new(StreamShared {
        state: Mutex::new(StreamState {
            tokens: VecDeque::new(),
            finished: None,
            finish_delivered: false,
            tx_alive: true,
            rx_alive: true,
            breakdown: None,
        }),
        readable: Condvar::new(),
        capacity: capacity.max(1),
        drain,
    });
    (
        EventSender {
            ch: Arc::clone(&ch),
        },
        EventReceiver { ch },
    )
}

impl EventSender {
    /// Enqueue one generated token if a slot is free. Never blocks.
    pub fn try_token(&self, token: u32) -> EmitResult {
        let mut g = self.ch.state.lock().unwrap();
        if !g.rx_alive {
            return EmitResult::Closed;
        }
        if g.tokens.len() >= self.ch.capacity {
            return EmitResult::Full;
        }
        g.tokens.push_back(token);
        drop(g);
        self.ch.readable.notify_one();
        EmitResult::Sent
    }

    /// Record the terminal event. Always succeeds (dedicated slot, not
    /// subject to the token capacity); the first finish wins.
    pub fn finish(&self, reason: FinishReason, usage: Usage) {
        let mut g = self.ch.state.lock().unwrap();
        if g.finished.is_none() && !g.finish_delivered {
            g.finished = Some((reason, usage));
        }
        drop(g);
        self.ch.readable.notify_one();
    }

    /// Current credit state, sampled by the engines before decoding.
    pub fn status(&self) -> StreamStatus {
        let g = self.ch.state.lock().unwrap();
        if !g.rx_alive {
            StreamStatus::Closed
        } else if g.tokens.len() >= self.ch.capacity {
            StreamStatus::Full
        } else {
            StreamStatus::Ready
        }
    }

    /// Undelivered tokens currently buffered.
    pub fn buffered(&self) -> usize {
        self.ch.state.lock().unwrap().tokens.len()
    }

    pub fn capacity(&self) -> usize {
        self.ch.capacity
    }

    /// Attach the request's lifecycle phase breakdown (engine-side, at
    /// span close). The first write wins, mirroring [`EventSender::finish`].
    pub fn set_breakdown(&self, b: SpanBreakdown) {
        let mut g = self.ch.state.lock().unwrap();
        if g.breakdown.is_none() {
            g.breakdown = Some(b);
        }
    }
}

impl Drop for EventSender {
    fn drop(&mut self) {
        let mut g = self.ch.state.lock().unwrap();
        g.tx_alive = false;
        drop(g);
        self.ch.readable.notify_one();
    }
}

impl EventReceiver {
    /// Tell the engine loop stream credit came back (a parked sequence
    /// may be resumable).
    fn notify_drain(&self) {
        if let Some(w) = &self.ch.drain {
            w.notify();
        }
    }

    /// True when popping one token just crossed the resume threshold
    /// (`policy::ready_to_resume`: buffered at most half capacity) —
    /// the only drain transition the engine ever acts on, so it is the
    /// only one worth the shared-wakeup notify (a per-token notify
    /// would serialize every fast-draining connection on one mutex).
    fn crossed_resume_threshold(&self, remaining: usize) -> bool {
        (remaining + 1) * 2 > self.ch.capacity && remaining * 2 <= self.ch.capacity
    }

    /// Next buffered event: tokens in order, then the terminal event.
    pub fn try_recv(&self) -> std::result::Result<GenEvent, TryRecvError> {
        let mut g = self.ch.state.lock().unwrap();
        if let Some(t) = g.tokens.pop_front() {
            let crossed = self.crossed_resume_threshold(g.tokens.len());
            drop(g);
            if crossed {
                self.notify_drain();
            }
            return Ok(GenEvent::Token(t));
        }
        if let Some((reason, usage)) = g.finished.take() {
            g.finish_delivered = true;
            return Ok(GenEvent::Finished { reason, usage });
        }
        if g.finish_delivered || !g.tx_alive {
            Err(TryRecvError::Closed)
        } else {
            Err(TryRecvError::Empty)
        }
    }

    /// Block until the next event; `Err` when the stream is over (the
    /// terminal event was already delivered, or the sender vanished
    /// without one).
    pub fn recv(&self) -> std::result::Result<GenEvent, RecvError> {
        let mut g = self.ch.state.lock().unwrap();
        loop {
            if let Some(t) = g.tokens.pop_front() {
                let crossed = self.crossed_resume_threshold(g.tokens.len());
                drop(g);
                if crossed {
                    self.notify_drain();
                }
                return Ok(GenEvent::Token(t));
            }
            if let Some((reason, usage)) = g.finished.take() {
                g.finish_delivered = true;
                return Ok(GenEvent::Finished { reason, usage });
            }
            if g.finish_delivered || !g.tx_alive {
                return Err(RecvError);
            }
            g = self.ch.readable.wait(g).unwrap();
        }
    }

    /// Undelivered tokens currently buffered (== the engine-side view).
    pub fn buffered(&self) -> usize {
        self.ch.state.lock().unwrap().tokens.len()
    }

    pub fn capacity(&self) -> usize {
        self.ch.capacity
    }

    /// The request's lifecycle phase breakdown, available once the
    /// engine closed its span (at finish). `None` while the request is
    /// live or for engines without span tracking.
    pub fn span_breakdown(&self) -> Option<SpanBreakdown> {
        self.ch.state.lock().unwrap().breakdown
    }
}

impl Drop for EventReceiver {
    fn drop(&mut self) {
        self.ch.state.lock().unwrap().rx_alive = false;
        // A disconnect is a wake condition too: the engine must reap.
        self.notify_drain();
    }
}

/// What [`InferenceEngine::submit`] hands back: the assigned id (usable
/// with `cancel`) and the per-request bounded event stream.
#[derive(Debug)]
pub struct SubmissionHandle {
    pub id: RequestId,
    pub events: EventReceiver,
}

impl SubmissionHandle {
    /// Token-buffer capacity of this request's stream.
    pub fn capacity(&self) -> usize {
        self.events.capacity()
    }

    /// This request's phase breakdown (queue wait, prefill, decode,
    /// paused, TTFT), available once it finished. See [`crate::obs`].
    pub fn span_breakdown(&self) -> Option<SpanBreakdown> {
        self.events.span_breakdown()
    }

    /// Drain every buffered event: generated tokens plus, once the
    /// request is over, its finish reason and usage record.
    pub fn drain(&self) -> (Vec<u32>, Option<(FinishReason, Usage)>) {
        let mut toks = Vec::new();
        let mut fin = None;
        while let Ok(ev) = self.events.try_recv() {
            match ev {
                GenEvent::Token(t) => toks.push(t),
                GenEvent::Finished { reason, usage } => fin = Some((reason, usage)),
            }
        }
        (toks, fin)
    }
}

/// The serving-engine abstraction. [`crate::engine::Engine`] (PJRT) and
/// [`crate::simengine::SimEngine`] (deterministic hash model) both
/// implement it over the same router / scheduler / KV-cache / policy
/// stack, so anything written against this trait — server, benches,
/// property tests — runs unchanged on either.
pub trait InferenceEngine {
    /// Queue a request; returns the assigned id and event stream.
    fn submit(&mut self, req: GenRequest) -> Result<SubmissionHandle>;

    /// Attach the engine-loop [`Wakeup`]: every stream the engine
    /// creates from now on notifies it when the client drains back
    /// across the resume threshold or disconnects, so a loop blocked on
    /// parked work wakes immediately instead of polling. Engines
    /// without flow control may ignore it (default no-op).
    fn set_wakeup(&mut self, _wakeup: Wakeup) {}

    /// Run one scheduling iteration (prefill, decode, or idle).
    fn step(&mut self) -> Result<Action>;

    /// Cancel a queued, running, or backpressure-paused request: its
    /// stream receives one final `Finished { reason: Cancelled, .. }`
    /// and every KV block it held is released. Returns `false` for
    /// unknown (or already finished) ids.
    fn cancel(&mut self, id: RequestId) -> Result<bool>;

    /// Cumulative engine metrics (counters, latency histograms,
    /// per-tenant usage).
    fn metrics(&self) -> &EngineMetrics;

    /// True when no work remains (queue empty, nothing running, nothing
    /// paused on backpressure).
    fn is_idle(&self) -> bool;

    fn queued(&self) -> usize;

    fn running(&self) -> usize;

    /// Sequences parked by stream backpressure (they hold KV but no
    /// decode lane). Zero for engines without flow control.
    fn paused(&self) -> usize {
        0
    }

    /// Instantaneous intake-queue depth per priority level, ascending
    /// by priority. Empty for engines without a priority queue.
    fn queue_depths(&self) -> Vec<(i32, usize)> {
        Vec::new()
    }

    /// The `{"stats": true}` snapshot: cumulative metrics plus the
    /// instantaneous queue/running/paused gauges and per-priority
    /// depths. Front-ends may merge their own fields (the server adds
    /// the request-registry depth) before serializing.
    fn stats_json(&self) -> Json {
        let mut j = self.metrics().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("queued".to_string(), Json::Num(self.queued() as f64));
            map.insert("running".to_string(), Json::Num(self.running() as f64));
            map.insert("paused".to_string(), Json::Num(self.paused() as f64));
            let depths = self
                .queue_depths()
                .into_iter()
                .map(|(p, n)| (p.to_string(), Json::Num(n as f64)))
                .collect();
            map.insert("queue_depths".to_string(), Json::Obj(depths));
        }
        j
    }

    /// The `{"admin": {"dump_flight": n}}` payload: the newest `n`
    /// entries of the engine's always-on flight recorder (see
    /// [`crate::obs::FlightRecorder`]). Engines without one return an
    /// empty dump.
    fn dump_flight(&self, _n: usize) -> Json {
        Json::obj(vec![
            ("capacity", Json::Num(0.0)),
            ("recorded", Json::Num(0.0)),
            ("dropped", Json::Num(0.0)),
            ("entries", Json::Arr(Vec::new())),
        ])
    }

    /// Engine-specific admin verbs beyond the protocol's common set
    /// (the fleet layer handles `drain_replica` / `kill_replica` /
    /// `fleet_stats` here). Returns `None` when the verb is not
    /// supported by this engine, which the server maps to a
    /// `bad_admin` error.
    fn admin(&mut self, _verb: &str, _arg: &Json) -> Option<Json> {
        None
    }

    /// Tokenize prompt text exactly the way `submit` would.
    fn encode(&self, text: &str) -> Vec<u32>;

    /// Decode generated ids to text.
    fn decode(&self, tokens: &[u32]) -> String;

    /// Drive until all submitted work is finished (offline mode).
    ///
    /// Note: with `BackpressurePolicy::PauseDecode`, a request whose
    /// handle is never drained parks once its stream fills and this
    /// loop will not terminate — offline callers must drain handles
    /// while stepping (as [`InferenceEngine::generate_text`] does) or
    /// size `stream_capacity` above their token budget.
    fn run_to_completion(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Offline helper: one blocking generation, decoded to text. Drains
    /// the stream while stepping and returns when *this* request's
    /// terminal event arrives, so it terminates for any
    /// `stream_capacity` and regardless of other submitted-but-undrained
    /// requests (which may be parked on backpressure indefinitely).
    fn generate_text(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<String> {
        let req = GenRequest::text(prompt)
            .params(params)
            .max_new_tokens(max_new_tokens);
        let handle = self.submit(req)?;
        let mut toks = Vec::new();
        loop {
            let (mut t, fin) = handle.drain();
            toks.append(&mut t);
            if fin.is_some() {
                break;
            }
            self.step()?;
        }
        Ok(self.decode(&toks))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_fields() {
        let r = GenRequest::text("hi")
            .client_id("abc")
            .tenant("acme")
            .priority(3)
            .stop(vec!["\n".into()])
            .max_new_tokens(7);
        assert_eq!(r.client_id.as_deref(), Some("abc"));
        assert_eq!(r.tenant, "acme");
        assert_eq!(r.priority, 3);
        assert_eq!(r.stop, vec!["\n".to_string()]);
        assert_eq!(r.max_new_tokens, 7);
        assert_eq!(r.prompt, Prompt::Text("hi".into()));
    }

    #[test]
    fn finish_reason_wire_names_are_stable() {
        for (r, s) in [
            (FinishReason::Eos, "eos"),
            (FinishReason::MaxTokens, "max_tokens"),
            (FinishReason::Stop, "stop"),
            (FinishReason::Cancelled, "cancelled"),
            (FinishReason::Preempted, "preempted"),
            (FinishReason::Overrun, "overrun"),
            (FinishReason::Error, "error"),
        ] {
            assert_eq!(r.as_str(), s);
        }
    }

    #[test]
    fn drain_collects_tokens_and_finish() {
        let (tx, rx) = event_channel(8);
        let h = SubmissionHandle { id: 1, events: rx };
        assert_eq!(tx.try_token(10), EmitResult::Sent);
        assert_eq!(tx.try_token(11), EmitResult::Sent);
        tx.finish(
            FinishReason::Eos,
            Usage {
                prompt_tokens: 4,
                cached_prompt_tokens: 0,
                prefill_tokens: 4,
                generated_tokens: 2,
            },
        );
        let (toks, fin) = h.drain();
        assert_eq!(toks, vec![10, 11]);
        let (reason, usage) = fin.unwrap();
        assert_eq!(reason, FinishReason::Eos);
        assert_eq!(usage.generated_tokens, 2);
        // The stream is over: further receives report Closed.
        assert!(matches!(h.events.try_recv(), Err(TryRecvError::Closed)));
    }

    #[test]
    fn stream_is_bounded_at_capacity() {
        let (tx, rx) = event_channel(2);
        assert_eq!(rx.capacity(), 2);
        assert_eq!(tx.try_token(1), EmitResult::Sent);
        assert_eq!(tx.try_token(2), EmitResult::Sent);
        assert_eq!(tx.status(), StreamStatus::Full);
        assert_eq!(tx.try_token(3), EmitResult::Full, "third token must not fit");
        assert_eq!(tx.buffered(), 2);
        // Draining one restores credit.
        assert!(matches!(rx.try_recv(), Ok(GenEvent::Token(1))));
        assert_eq!(tx.status(), StreamStatus::Ready);
        assert_eq!(tx.try_token(3), EmitResult::Sent);
    }

    #[test]
    fn finish_lands_even_when_token_buffer_is_full() {
        let (tx, rx) = event_channel(1);
        assert_eq!(tx.try_token(7), EmitResult::Sent);
        assert_eq!(tx.try_token(8), EmitResult::Full);
        tx.finish(FinishReason::Overrun, Usage::default());
        let h = SubmissionHandle { id: 1, events: rx };
        let (toks, fin) = h.drain();
        assert_eq!(toks, vec![7], "buffered token survives");
        assert_eq!(fin.unwrap().0, FinishReason::Overrun);
    }

    #[test]
    fn span_breakdown_rides_the_stream_first_write_wins() {
        let (tx, rx) = event_channel(4);
        assert_eq!(rx.span_breakdown(), None, "live request has no span yet");
        let b = SpanBreakdown {
            queue_wait_us: 10,
            total_us: 10,
            ..SpanBreakdown::default()
        };
        tx.set_breakdown(b);
        tx.set_breakdown(SpanBreakdown {
            queue_wait_us: 999,
            ..SpanBreakdown::default()
        });
        let h = SubmissionHandle { id: 1, events: rx };
        assert_eq!(h.span_breakdown(), Some(b), "first write wins");
    }

    #[test]
    fn dropped_receiver_reports_closed() {
        let (tx, rx) = event_channel(4);
        drop(rx);
        assert_eq!(tx.status(), StreamStatus::Closed);
        assert_eq!(tx.try_token(1), EmitResult::Closed);
    }

    #[test]
    fn dropped_sender_unblocks_receiver() {
        let (tx, rx) = event_channel(4);
        assert_eq!(tx.try_token(5), EmitResult::Sent);
        drop(tx);
        assert!(matches!(rx.recv(), Ok(GenEvent::Token(5))));
        assert!(
            matches!(rx.recv(), Err(RecvError)),
            "no terminal event: stream ends"
        );
        assert!(matches!(rx.try_recv(), Err(TryRecvError::Closed)));
    }

    #[test]
    fn zero_capacity_is_floored_to_one() {
        let (tx, _rx) = event_channel(0);
        assert_eq!(tx.capacity(), 1);
        assert_eq!(tx.try_token(1), EmitResult::Sent);
        assert_eq!(tx.try_token(2), EmitResult::Full);
    }

    #[test]
    fn wakeup_epoch_closes_the_check_then_wait_race() {
        let w = Wakeup::new();
        let seen = w.epoch();
        // A notification *between* the epoch capture and the wait must
        // make the wait return immediately (no timeout sleep).
        w.notify();
        let t0 = std::time::Instant::now();
        assert!(w.wait_from(seen, Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_secs(1), "must not block");
        // Nothing new: the wait times out.
        assert!(!w.wait_from(w.epoch(), Duration::from_millis(1)));
    }

    #[test]
    fn wakeup_crosses_threads() {
        let w = Wakeup::new();
        let seen = w.epoch();
        let w2 = w.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            w2.notify();
        });
        assert!(w.wait_from(seen, Duration::from_secs(10)), "notified");
        t.join().unwrap();
    }

    #[test]
    fn stream_drain_notifies_exactly_at_the_resume_threshold() {
        let w = Wakeup::new();
        let (tx, rx) = event_channel_with_wakeup(4, Some(w.clone()));
        for t in 0..4 {
            assert_eq!(tx.try_token(t), EmitResult::Sent);
        }
        // 4 -> 3 buffered: still above half capacity, engine would not
        // resume, so no notify (fast clients must not hammer the lock).
        let seen = w.epoch();
        assert!(matches!(rx.try_recv(), Ok(GenEvent::Token(0))));
        assert_eq!(w.epoch(), seen, "above-threshold drain stays silent");
        // 3 -> 2 buffered: crosses `buffered*2 <= capacity` — exactly
        // the `ready_to_resume` transition — and must notify.
        assert!(matches!(rx.try_recv(), Ok(GenEvent::Token(1))));
        assert_ne!(w.epoch(), seen, "threshold crossing must notify");
        // Further drains below the threshold stay silent again.
        let seen = w.epoch();
        assert!(matches!(rx.try_recv(), Ok(GenEvent::Token(2))));
        assert_eq!(w.epoch(), seen, "below-threshold drain stays silent");
        // Disconnect always notifies (the engine must reap).
        drop(rx);
        assert_ne!(w.epoch(), seen, "disconnect must notify");
    }

    #[test]
    fn capacity_one_stream_notifies_on_every_pop_to_empty() {
        // With capacity 1 the resume threshold is an empty buffer, so
        // each pop-to-empty is a crossing and must wake the engine.
        let w = Wakeup::new();
        let (tx, rx) = event_channel_with_wakeup(1, Some(w.clone()));
        assert_eq!(tx.try_token(9), EmitResult::Sent);
        let seen = w.epoch();
        assert!(matches!(rx.try_recv(), Ok(GenEvent::Token(9))));
        assert_ne!(w.epoch(), seen, "pop to empty is the resume crossing");
    }
}
