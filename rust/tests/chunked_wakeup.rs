//! The engine-loop nap/wakeup contract re-validated under chunked
//! decode: when every live request is parked on backpressure, a client
//! draining its stream across the resume threshold must advance the
//! [`Wakeup`] epoch *without any engine step* (the drain path itself
//! notifies — this is what `server::engine_loop` blocks on), and the
//! resume latency in engine steps must be identical at every chunk
//! size. Chunking fuses policy work across rounds, but the pause is
//! observed mid-chunk (credit is checked before every token), so a
//! parked world looks exactly the same to the loop at chunk 1 and
//! chunk 4.

use fdpp::api::{GenEvent, GenRequest, InferenceEngine, Wakeup};
use fdpp::config::{BackpressurePolicy, EngineConfig};
use fdpp::scheduler::Action;
use fdpp::simengine::{SimEngine, SimSpec};

struct ParkedRun {
    /// Tokens emitted before the stream filled and the engine parked.
    tokens_at_pause: u64,
    /// Epoch delta produced by the first drain alone (no engine step).
    epoch_advanced: bool,
    /// Engine steps from that drain until the next token appeared.
    resume_latency: u64,
    /// Tokens delivered over the request's whole life.
    total_tokens: usize,
}

/// Drive one request into a backpressure park (capacity-2 stream,
/// nobody reading), then drain client-side and measure how the wakeup
/// and the resume behave.
fn run_parked_world(chunk: usize) -> ParkedRun {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 64,
        max_new_tokens: 12,
        max_running: 1,
        stream_capacity: 2,
        backpressure: BackpressurePolicy::PauseDecode,
        decode_chunk: chunk,
        seed: 7,
        ..EngineConfig::default()
    };
    let mut engine = SimEngine::new(cfg, SimSpec::default()).expect("engine builds");
    let w = Wakeup::new();
    engine.set_wakeup(w.clone());
    let h = engine
        .submit(GenRequest::text("wakeup probe prompt").max_new_tokens(12))
        .expect("submit accepted");

    // Phase 1: nobody drains; the stream fills and the engine parks
    // the sequence. `Action::Idle` with work still live is exactly the
    // state `engine_loop` naps on.
    let mut guard = 0;
    loop {
        assert!(guard < 1000, "chunk {chunk}: engine never parked");
        guard += 1;
        let action = engine.step().expect("step succeeds");
        if action == Action::Idle {
            break;
        }
    }
    assert!(!engine.is_idle(), "parked is not finished");
    let tokens_at_pause = engine.metrics.tokens_generated;

    // Phase 2: one client-side drain crosses the resume threshold
    // (capacity 2: buffered 2 -> 1 crosses half). The epoch must
    // advance from the drain alone — no engine step in between.
    let e0 = w.epoch();
    let mut drained = 0usize;
    assert!(h.events.try_recv().is_ok(), "a buffered token is waiting");
    drained += 1;
    let epoch_advanced = w.epoch() > e0;

    // Phase 3: eager from here on; count steps until the engine emits
    // again, then drain to completion.
    let mut resume_latency = 0u64;
    let mut finished = false;
    let mut seen_resume = false;
    let mut guard = 0;
    while !engine.is_idle() {
        assert!(guard < 1000, "chunk {chunk}: engine never drained");
        guard += 1;
        let before = engine.metrics.tokens_generated;
        engine.step().expect("step succeeds");
        if !seen_resume {
            resume_latency += 1;
            seen_resume = engine.metrics.tokens_generated > before;
        }
        while let Ok(ev) = h.events.try_recv() {
            match ev {
                GenEvent::Token(_) => drained += 1,
                GenEvent::Finished { .. } => finished = true,
            }
        }
    }
    assert!(finished, "chunk {chunk}: request must finish");
    ParkedRun {
        tokens_at_pause,
        epoch_advanced,
        resume_latency,
        total_tokens: drained,
    }
}

#[test]
fn drain_wakes_parked_engine_without_a_step_at_any_chunk() {
    let base = run_parked_world(1);
    assert!(
        base.epoch_advanced,
        "chunk 1: client drain must notify the wakeup with no engine step"
    );
    assert_eq!(base.total_tokens, 12, "chunk 1: full budget delivered");
    for chunk in [2usize, 4, 8] {
        let run = run_parked_world(chunk);
        assert!(
            run.epoch_advanced,
            "chunk {chunk}: client drain must notify the wakeup with no engine step"
        );
        assert_eq!(
            run.tokens_at_pause, base.tokens_at_pause,
            "chunk {chunk}: credit must gate every token, so the park \
             happens at the same point in the token stream"
        );
        assert_eq!(
            run.resume_latency, base.resume_latency,
            "chunk {chunk}: resume latency in engine steps must match chunk 1"
        );
        assert_eq!(run.total_tokens, 12, "chunk {chunk}: full budget delivered");
    }
}
