//! Property-based tests over the coordinator invariants (in-tree
//! randomized harness; DESIGN.md §8): KV-cache block conservation,
//! batcher FIFO/budget, dispatch totality/monotonicity, scheduler
//! conservation, Eq. 5 monotonicity, JSON round-trips.

use fdpp::batching::{pick_bucket, Batcher};
use fdpp::dataflow::{find_inflections, ImplKind, LookupTable, OpInflection};
use fdpp::gemm::compute_memory_ratio;
use fdpp::kvcache::{KvCache, KvGeometry};
use fdpp::scheduler::{decide, Action, SchedState};
use fdpp::util::json;
use fdpp::util::rng::Rng;

const CASES: usize = 200;

fn geo(rng: &mut Rng) -> KvGeometry {
    KvGeometry {
        n_layers: rng.gen_range(1, 3),
        n_heads: rng.gen_range(1, 3),
        head_dim: 4 * rng.gen_range(1, 3),
        block_tokens: [4, 8, 16][rng.gen_range(0, 2)],
        max_seq: 64,
    }
}

/// KV cache never double-allocates, never leaks, and free+used is
/// constant under random alloc/grow/free sequences.
#[test]
fn prop_kvcache_block_conservation() {
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for case in 0..CASES {
        let g = geo(&mut rng);
        let total = rng.gen_range(4, 32);
        let mut kv = KvCache::new(g, total);
        let mut live: Vec<u64> = vec![];
        for op in 0..50 {
            match rng.gen_range(0, 2) {
                0 => {
                    let id = (case * 1000 + op) as u64;
                    let toks = rng.gen_range(1, g.max_seq);
                    if kv.alloc_seq(id, toks).is_ok() {
                        assert!(!live.contains(&id));
                        live.push(id);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let id = live[rng.gen_range(0, live.len() - 1)];
                        let _ = kv.grow_one(id);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.gen_range(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        kv.free_seq(id).unwrap();
                    }
                }
            }
            assert_eq!(kv.used_blocks() + kv.free_blocks(), total, "block leak");
        }
        for id in live {
            kv.free_seq(id).unwrap();
        }
        assert_eq!(kv.free_blocks(), total, "blocks must all return");
    }
}

/// Batcher (sticky lanes): membership preserved, occupancy fits the
/// bucket, holes only appear where sequences left, lanes never shift
/// except across a shrink, and shrink only fires when occupancy fits a
/// smaller bucket.
#[test]
fn prop_batcher_sticky_lanes() {
    let mut rng = Rng::seed_from_u64(0xBEEF);
    for _ in 0..CASES {
        let buckets = vec![1, 2, 4, 8];
        let mut b = Batcher::new(buckets.clone());
        let mut live: Vec<u64> = vec![];
        let mut next_id = 0u64;
        let mut prev: Option<Vec<Option<u64>>> = None;
        for _ in 0..40 {
            let mut layout_may_change = false;
            match rng.gen_range(0, 2) {
                0 if live.len() < 8 => {
                    let adm = b.admit(next_id).unwrap();
                    assert!(adm.lane < b.bucket());
                    live.push(next_id);
                    next_id += 1;
                    // joining must never move existing lanes
                    if let (Some(p), false) = (&prev, adm.bucket_grew) {
                        let cur = b.assemble().unwrap().lanes;
                        for (i, slot) in p.iter().enumerate() {
                            if slot.is_some() {
                                assert_eq!(cur[i], *slot, "sticky lane moved");
                            }
                        }
                    }
                    layout_may_change = true;
                }
                1 if !live.is_empty() => {
                    let idx = rng.gen_range(0, live.len() - 1);
                    let id = live.swap_remove(idx);
                    let shrank = b.remove(id).unwrap();
                    if shrank {
                        layout_may_change = true;
                        assert!(
                            live.len() <= pick_bucket(&buckets, live.len().max(1)).unwrap()
                        );
                    }
                    layout_may_change = true;
                }
                _ => {}
            }
            let _ = layout_may_change;
            assert_eq!(b.len(), live.len());
            if live.is_empty() {
                assert!(b.assemble().is_err());
                prev = None;
                continue;
            }
            let batch = b.assemble().unwrap();
            assert_eq!(batch.occupancy(), live.len());
            assert!(buckets.contains(&batch.bucket));
            assert!(batch.bucket >= live.len(), "bucket too small");
            // every live id has exactly one lane
            for id in &live {
                assert_eq!(
                    batch.lanes.iter().filter(|l| **l == Some(*id)).count(),
                    1,
                    "seq {id} lane count"
                );
            }
            prev = Some(batch.lanes);
        }
    }
}

/// Dispatch is total and monotone in M: the chosen impl only ever moves
/// A -> B -> C as M grows, for any profiler (even adversarial ones).
#[test]
fn prop_dispatch_total_and_monotone() {
    let mut rng = Rng::seed_from_u64(0xD15);
    for case in 0..CASES {
        let ms = vec![1, 2, 4, 8, 16, 32, 64, 128];
        // adversarial random profiler
        let seed = case as u64;
        let mut profiler = move |ik: ImplKind, m: usize| -> fdpp::Result<f64> {
            let mut r = Rng::seed_from_u64(
                seed ^ (m as u64) << 3
                    ^ match ik {
                        ImplKind::A => 1,
                        ImplKind::B => 2,
                        ImplKind::C => 3,
                    },
            );
            Ok(r.next_f64())
        };
        let inf = find_inflections("x", 64, 64, &ms, &mut profiler).unwrap();
        assert!(inf.m1 <= inf.m2, "m1 {} > m2 {}", inf.m1, inf.m2);
        let mut rank_prev = 0u8;
        for m in 0..300 {
            let ik = inf.dispatch(m);
            let rank = match ik {
                ImplKind::A => 0,
                ImplKind::B => 1,
                ImplKind::C => 2,
            };
            assert!(rank >= rank_prev, "dispatch regressed at M={m}");
            rank_prev = rank;
        }
        let _ = rng.next_u64();
    }
}

/// Scheduler conserves work: it never invents an action with nothing to
/// do, never idles when work exists.
#[test]
fn prop_scheduler_no_lost_work() {
    let mut rng = Rng::seed_from_u64(0x5EED);
    for _ in 0..CASES * 5 {
        let next_prefill_blocks = rng.gen_range(0, 8);
        let s = SchedState {
            queued: rng.gen_range(0, 5),
            running: rng.gen_range(0, 8),
            max_running: 8,
            free_blocks: rng.gen_range(0, 16),
            next_prefill_blocks,
            cached_prefill_blocks: rng.gen_range(0, next_prefill_blocks.max(1)),
        };
        let a = decide(s);
        match a {
            Action::Idle => assert!(s.queued == 0 && s.running == 0),
            Action::Decode => assert!(s.running > 0),
            Action::Prefill => assert!(s.queued > 0),
        }
        if s.queued + s.running > 0 {
            assert_ne!(a, Action::Idle, "idle with work present: {s:?}");
        }
    }
}

/// Eq. 5 monotonicity: the compute/memory ratio increases with B_N and
/// with M, and is bounded by 2*M (the K -> inf limit... actually 2*M*K/(K/1) bound).
#[test]
fn prop_eq5_monotone() {
    let mut rng = Rng::seed_from_u64(0xE05);
    for _ in 0..CASES {
        let m = rng.gen_range(1, 64);
        let k = 64 * rng.gen_range(1, 128);
        let bn1 = 8 * rng.gen_range(1, 32);
        let bn2 = bn1 * 2;
        let r1 = compute_memory_ratio(m, k, bn1);
        let r2 = compute_memory_ratio(m, k, bn2);
        assert!(r2 >= r1, "ratio must grow with B_N");
        let rm = compute_memory_ratio(m + 1, k, bn1);
        assert!(rm >= r1, "ratio must grow with M");
        assert!(r1 > 0.0 && r1 <= 2.0 * m as f64);
    }
}

/// Lookup tables survive JSON round trips byte-for-byte semantically.
#[test]
fn prop_lookup_table_json_roundtrip() {
    let mut rng = Rng::seed_from_u64(0x10AD);
    for case in 0..CASES {
        let entries: Vec<OpInflection> = (0..rng.gen_range(1, 4))
            .map(|i| {
                let m1 = rng.gen_range(1, 64);
                OpInflection {
                    op: format!("op{i}"),
                    n: rng.gen_range(1, 20000),
                    k: rng.gen_range(1, 20000),
                    m1,
                    m2: m1 + rng.gen_range(0, 512),
                }
            })
            .collect();
        let t = LookupTable {
            model: format!("m{case}"),
            hardware: "hw".into(),
            entries,
        };
        let j = t.to_json().to_string();
        let back = LookupTable::from_json(&json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.model, t.model);
        assert_eq!(back.entries.len(), t.entries.len());
        for (a, b) in back.entries.iter().zip(&t.entries) {
            assert_eq!((a.m1, a.m2, a.n, a.k, &a.op), (b.m1, b.m2, b.n, b.k, &b.op));
        }
    }
}

/// Random JSON values round-trip through the in-tree serializer/parser.
#[test]
fn prop_json_roundtrip_fuzz() {
    fn gen_value(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { rng.gen_range(0, 3) } else { rng.gen_range(0, 5) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.next_u64() % 2 == 0),
            2 => json::Json::Num((rng.next_f64() * 2e6) - 1e6),
            3 => json::Json::Arr(
                (0..rng.gen_range(0, 4)).map(|_| gen_value(rng, depth - 1)).collect(),
            ),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.gen_range(0, 4) {
                    m.insert(format!("k{i}\"\n→"), gen_value(rng, depth - 1));
                }
                json::Json::Obj(m)
            }
        }
    }
    let mut rng = Rng::seed_from_u64(0xF022);
    for _ in 0..CASES {
        let v = gen_value(&mut rng, 3);
        let text = v.to_string();
        let back = json::parse(&text).unwrap();
        // numbers may lose a ulp through the f64 formatter; compare text
        assert_eq!(back.to_string(), text);
    }
}
