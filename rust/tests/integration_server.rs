//! Loopback server integration: the exact `serve` plumbing — accept
//! loop, connection handler, engine thread, wire protocol — driven
//! against a [`SimEngine`]-backed [`InferenceEngine`] on 127.0.0.1, so
//! the whole request path runs on a bare checkout (no PJRT artifacts).
//!
//! Covers generate (with the `accepted` ack, id echo and usage
//! accounting), stats (per-tenant counters, registry depth, queue
//! depths, backpressure counters), cancel (ack + `cancelled` done line,
//! including *cross-connection* cancellation by global id and the admin
//! bulk-cancel verb), stop sequences over the wire, budget clamping,
//! the structured-error validation path, slow-client isolation (a
//! stalled reader never delays other connections' streams), the
//! v2.3 observability surface: the `done` line's span breakdown, the
//! `dump_flight` admin verb, and the Prometheus stats rendering — and
//! the v2.4 fleet surface: a sim-backed [`fdpp::fleet::Fleet`] behind
//! the same loop, the `drain_replica` / `kill_replica` / `fleet_stats`
//! admin verbs, and mid-stream replica death with resubmission.

use std::net::TcpListener;
use std::thread;
use std::time::{Duration, Instant};

use fdpp::api::{GenRequest, InferenceEngine};
use fdpp::config::{EngineConfig, FleetConfig, RoutePolicy};
use fdpp::server::{serve_on, spawn_sim_engine, spawn_sim_fleet, Client};
use fdpp::simengine::{SimEngine, SimSpec};
use fdpp::util::json::{parse, Json};

fn test_cfg() -> EngineConfig {
    EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 128,
        max_new_tokens: 32,
        prefix_cache: true,
        ..EngineConfig::default()
    }
}

/// Bind port 0, spawn the sim-backed engine thread, run the production
/// accept loop on it, and return the dialable address.
fn start_server_with(cfg: EngineConfig, spec: SimSpec) -> String {
    let vocab = spec.vocab;
    let max_new_cap = cfg.max_new_tokens;
    let handle = spawn_sim_engine(cfg, spec).expect("sim engine starts");
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = serve_on(listener, handle, vocab, max_new_cap);
    });
    addr
}

fn start_server(cfg: EngineConfig) -> String {
    start_server_with(cfg, SimSpec::default())
}

/// The deterministic full generation for a prompt, straight from a
/// local sim engine (what the server must reproduce over the wire).
fn local_generation(prompt: &str, max_new_tokens: usize) -> Vec<u32> {
    let mut e = SimEngine::new(test_cfg(), SimSpec::default()).unwrap();
    let h = e
        .submit(GenRequest::text(prompt).max_new_tokens(max_new_tokens))
        .unwrap();
    e.run_to_completion().unwrap();
    let (toks, _) = h.drain();
    toks
}

/// A prompt whose greedy generation runs for at least `min_tokens`
/// (stable: the hash model is deterministic per prompt).
fn long_running_prompt(min_tokens: usize, budget: usize) -> (String, Vec<u32>) {
    for salt in 0..64u32 {
        let prompt = format!("server probe {salt}");
        let toks = local_generation(&prompt, budget);
        if toks.len() >= min_tokens {
            return (prompt, toks);
        }
    }
    panic!("no prompt survived {min_tokens} tokens");
}

/// A long-budget config + prompt pair guaranteed (by a deterministic
/// local probe) to run its full budget, so a cancel always lands
/// mid-generation over the wire.
fn cancelable_workload(budget: usize) -> (EngineConfig, SimSpec, String) {
    let spec = SimSpec {
        vocab: 32000,
        max_seq: 1024,
        ..SimSpec::default()
    };
    let cfg = EngineConfig {
        max_new_tokens: budget,
        kv_total_blocks: 256,
        stream_capacity: budget + 8,
        ..test_cfg()
    };
    let prompt = (0..16u32)
        .map(|salt| format!("cancel probe {salt}"))
        .find(|p| {
            let mut e = SimEngine::new(cfg.clone(), spec).unwrap();
            let h = e
                .submit(GenRequest::text(p.as_str()).max_new_tokens(budget))
                .unwrap();
            e.run_to_completion().unwrap();
            h.drain().0.len() == budget
        })
        .expect("some probe must run its full budget without EOS");
    (cfg, spec, prompt)
}

/// Read lines until the `accepted` ack, returning the global id.
fn read_accepted(c: &mut Client, wire_id: &str) -> String {
    let j = c.recv().unwrap();
    assert_eq!(
        j.get("accepted").and_then(Json::as_bool),
        Some(true),
        "first line must be the accepted ack, got {}",
        j.to_string()
    );
    assert_eq!(j.req_str("id").unwrap(), wire_id);
    j.req_str("global").unwrap()
}

#[test]
fn generate_echoes_id_and_reports_usage() {
    let addr = start_server(test_cfg());
    let mut c = Client::connect(&addr).unwrap();
    c.send(&Json::obj(vec![
        ("id", Json::Str("req-1".into())),
        ("prompt", Json::Str("hello loopback server".into())),
        ("max_new_tokens", Json::Num(6.0)),
    ]))
    .unwrap();
    let global = read_accepted(&mut c, "req-1");
    assert!(global.starts_with('g'), "global ids look like g<N>: {global}");
    let mut tokens = Vec::new();
    let done = loop {
        let j = c.recv().unwrap();
        assert!(j.get("error").is_none(), "unexpected error: {}", j.to_string());
        assert_eq!(j.req_str("id").unwrap(), "req-1", "every line carries the id");
        if j.get("done").is_some() {
            break j;
        }
        tokens.push(j.req_usize("token").unwrap() as u32);
    };
    assert!(!tokens.is_empty());
    assert_eq!(done.req_usize("n").unwrap(), tokens.len());
    let usage = done.field("usage").unwrap();
    assert_eq!(usage.req_usize("generated_tokens").unwrap(), tokens.len());
    // BOS + one token per byte of the prompt.
    assert_eq!(
        usage.req_usize("prompt_tokens").unwrap(),
        "hello loopback server".len() + 1
    );
    assert_eq!(
        usage.req_usize("cached_tokens").unwrap() + usage.req_usize("prefill_tokens").unwrap(),
        usage.req_usize("prompt_tokens").unwrap()
    );
    // And the stream matches the engine run bit for bit.
    assert_eq!(tokens, local_generation("hello loopback server", 6));
}

#[test]
fn stats_exposes_per_tenant_counters_and_flow_control_fields() {
    let addr = start_server(test_cfg());
    let mut c = Client::connect(&addr).unwrap();
    c.send(&Json::obj(vec![
        ("prompt", Json::Str("tenant accounting probe".into())),
        ("tenant", Json::Str("acme".into())),
        ("max_new_tokens", Json::Num(4.0)),
    ]))
    .unwrap();
    // Drain the generation (accepted line, tokens, done).
    loop {
        let j = c.recv().unwrap();
        if j.get("done").is_some() {
            break;
        }
    }
    let stats = c.stats().unwrap();
    let j = fdpp::util::json::parse(&stats).unwrap();
    assert!(j.req_usize("tokens_generated").unwrap() >= 1);
    let acme = j.field("tenants").unwrap().field("acme").unwrap();
    assert_eq!(acme.req_usize("requests_finished").unwrap(), 1);
    assert!(acme.req_usize("generated_tokens").unwrap() >= 1);
    // v2.1 snapshot fields: registry depth, engine gauges, per-priority
    // queue depths, backpressure counters.
    assert_eq!(j.req_usize("registry_depth").unwrap(), 0, "nothing in flight");
    assert_eq!(j.req_usize("queued").unwrap(), 0);
    assert_eq!(j.req_usize("running").unwrap(), 0);
    assert_eq!(j.req_usize("paused").unwrap(), 0);
    assert!(j.field("queue_depths").is_ok());
    assert_eq!(j.req_usize("backpressure_pauses").unwrap(), 0);
    assert_eq!(j.req_usize("backpressure_drops").unwrap(), 0);
    // Core-split additions: the audit verdict the simulation oracles
    // check, surfaced on the production stats path, plus the dedup and
    // quota counters.
    assert_eq!(
        j.get("kv_refcount_ok").and_then(Json::as_bool),
        Some(true),
        "a healthy engine audits clean over the wire"
    );
    assert_eq!(j.req_usize("blocks_leaked").unwrap(), 0);
    assert_eq!(
        j.get("trace_enabled").and_then(Json::as_bool),
        Some(false),
        "tracing is off by default in production"
    );
    assert_eq!(j.req_usize("dedup_hits").unwrap(), 0);
    assert_eq!(j.req_usize("quota_rejections").unwrap(), 0);
}

#[test]
fn tenant_quota_rejections_surface_as_quota_exceeded() {
    // Quota 1 + a 2-slot stream that parks its undrained request: the
    // first submission stays in flight deterministically, so the second
    // must be rejected with the structured quota code.
    let budget = 600;
    let (base_cfg, spec, prompt) = cancelable_workload(budget);
    let cfg = EngineConfig {
        tenant_max_inflight: 1,
        stream_capacity: 2,
        ..base_cfg
    };
    let addr = start_server_with(cfg, spec);
    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c.send(&Json::obj(vec![
        ("id", Json::Str("q1".into())),
        ("prompt", Json::Str(prompt.clone())),
        ("tenant", Json::Str("acme".into())),
        ("max_new_tokens", Json::Num(budget as f64)),
    ]))
    .unwrap();
    let _global = read_accepted(&mut c, "q1");

    // Same tenant, second request: structured quota_exceeded (not the
    // generic "rejected"), and the error names the tenant.
    c.send(&Json::obj(vec![
        ("id", Json::Str("q2".into())),
        ("prompt", Json::Str("second acme request".into())),
        ("tenant", Json::Str("acme".into())),
        ("max_new_tokens", Json::Num(3.0)),
    ]))
    .unwrap();
    let mut saw_quota_error = false;
    while !saw_quota_error {
        let j = c.recv().unwrap();
        if j.get("error").is_some() {
            assert_eq!(j.req_str("code").unwrap(), "quota_exceeded");
            assert!(j.req_str("error").unwrap().contains("acme"));
            saw_quota_error = true;
        } else {
            // q1's token lines may interleave before the error.
            assert!(
                j.get("token").is_some(),
                "unexpected line: {}",
                j.to_string()
            );
        }
    }

    // A different tenant is admitted despite acme being at its limit.
    let mut other = Client::connect(&addr).unwrap();
    other.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    other
        .send(&Json::obj(vec![
            ("id", Json::Str("g1".into())),
            ("prompt", Json::Str("globex request".into())),
            ("tenant", Json::Str("globex".into())),
            ("max_new_tokens", Json::Num(3.0)),
        ]))
        .unwrap();
    let _g = read_accepted(&mut other, "g1");

    // Cancel q1 to free the slot: the same submission now succeeds.
    // (The {"ok"} ack and the done line come from different threads and
    // may interleave with trailing token lines in either order.)
    c.cancel("q1").unwrap();
    let mut done = false;
    let mut saw_ack = false;
    while !done || !saw_ack {
        let j = c.recv().unwrap();
        if j.get("ok").is_some() {
            saw_ack = true;
        } else if j.get("done").is_some() {
            assert_eq!(j.req_str("reason").unwrap(), "cancelled");
            done = true;
        }
    }
    c.send(&Json::obj(vec![
        ("id", Json::Str("q3".into())),
        ("prompt", Json::Str("third acme request".into())),
        ("tenant", Json::Str("acme".into())),
        ("max_new_tokens", Json::Num(3.0)),
    ]))
    .unwrap();
    let _global = read_accepted(&mut c, "q3");
    // And the stats path counts the rejection.
    let stats = c.stats().unwrap();
    let j = fdpp::util::json::parse(&stats).unwrap();
    assert_eq!(j.req_usize("quota_rejections").unwrap(), 1);
}

#[test]
fn cancel_mid_generation_reports_cancelled() {
    // Determinism plan: a huge sim vocab makes EOS very unlikely per
    // step, and the probe verifies (the hash model is deterministic per
    // prompt) that the chosen prompt runs its full budget uncancelled.
    // Over the wire, those several hundred decode steps take orders of
    // magnitude longer than the cancel round trip, so the cancel always
    // lands mid-decode.
    let budget = 600;
    let (cfg, spec, prompt) = cancelable_workload(budget);
    let addr = start_server_with(cfg, spec);

    let mut c = Client::connect(&addr).unwrap();
    // Fail loudly (recv error) rather than hanging if a timing
    // assumption is ever violated on a pathological machine.
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c.send(&Json::obj(vec![
        ("id", Json::Str("c1".into())),
        ("prompt", Json::Str(prompt)),
        ("max_new_tokens", Json::Num(budget as f64)),
    ]))
    .unwrap();
    let _global = read_accepted(&mut c, "c1");
    // Wait for the first streamed token (the request is in-flight), then
    // poke the duplicate-id guard and cancel.
    let first = c.recv().unwrap();
    assert!(first.get("token").is_some(), "got {}", first.to_string());
    c.send(&Json::obj(vec![
        ("id", Json::Str("c1".into())),
        ("prompt", Json::Str("same id while in flight".into())),
        ("max_new_tokens", Json::Num(3.0)),
    ]))
    .unwrap();
    c.cancel("c1").unwrap();
    // Lines now interleave: more tokens, the duplicate_id error, the
    // {"ok":true} ack, and the done line (distinct writer threads race;
    // read until everything arrived).
    let mut saw_ack = false;
    let mut saw_duplicate = false;
    let mut reason: Option<String> = None;
    let mut streamed = 1usize;
    while reason.is_none() || !saw_ack || !saw_duplicate {
        let j = c.recv().unwrap();
        if j.get("accepted").is_some() {
            continue;
        } else if j.get("ok").is_some() {
            saw_ack = true;
        } else if j.get("error").is_some() {
            assert_eq!(j.req_str("code").unwrap(), "duplicate_id");
            saw_duplicate = true;
        } else if j.get("done").is_some() {
            reason = Some(j.req_str("reason").unwrap());
        } else {
            assert!(j.get("token").is_some(), "unexpected line: {}", j.to_string());
            streamed += 1;
        }
    }
    assert_eq!(reason.as_deref(), Some("cancelled"));
    assert!(
        streamed < budget,
        "cancellation must land before the budget is exhausted"
    );

    // The id was pruned when the done line went out: cancelling again
    // is now a structured unknown_id error.
    c.cancel("c1").unwrap();
    let j = c.recv().unwrap();
    assert_eq!(j.req_str("code").unwrap(), "unknown_id");

    // The engine is idle again and serves new work on the same socket.
    let out = c.generate("after cancel", 3);
    assert!(out.is_ok());
}

#[test]
fn cancel_from_another_connection_by_global_id() {
    let budget = 600;
    let (cfg, spec, prompt) = cancelable_workload(budget);
    let addr = start_server_with(cfg, spec);

    // Connection A submits and reads its global id from the ack.
    let mut a = Client::connect(&addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    a.send(&Json::obj(vec![
        ("id", Json::Str("mine".into())),
        ("prompt", Json::Str(prompt)),
        ("max_new_tokens", Json::Num(budget as f64)),
    ]))
    .unwrap();
    let global = read_accepted(&mut a, "mine");
    let first = a.recv().unwrap();
    assert!(first.get("token").is_some(), "request is streaming");

    // Connection B — which never submitted anything — cancels it by the
    // global id.
    let mut b = Client::connect(&addr).unwrap();
    b.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    b.cancel(&global).unwrap();
    let ack = b.recv().unwrap();
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(ack.req_str("id").unwrap(), global);

    // Connection A's stream terminates with reason "cancelled".
    let mut streamed = 1usize;
    let reason = loop {
        let j = a.recv().unwrap();
        if j.get("done").is_some() {
            break j.req_str("reason").unwrap();
        }
        streamed += 1;
    };
    assert_eq!(reason, "cancelled");
    assert!(streamed < budget, "cancel landed mid-generation");

    // KV fully reclaimed: the engine serves new work and reports the
    // cancellation; the registry entry is pruned.
    let stats = fdpp::util::json::parse(&b.stats().unwrap()).unwrap();
    assert!(stats.req_usize("cancellations").unwrap() >= 1);
    assert_eq!(stats.req_usize("registry_depth").unwrap(), 0);
    // A cancel for the now-dead global id is unknown.
    b.cancel(&global).unwrap();
    let j = b.recv().unwrap();
    assert_eq!(j.req_str("code").unwrap(), "unknown_id");
}

#[test]
fn admin_cancel_tenant_bulk_cancels_across_connections() {
    let budget = 600;
    let (cfg, spec, prompt) = cancelable_workload(budget);
    let addr = start_server_with(cfg, spec);

    let mut a = Client::connect(&addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    for id in ["t1", "t2"] {
        a.send(&Json::obj(vec![
            ("id", Json::Str(id.into())),
            ("prompt", Json::Str(prompt.clone())),
            ("tenant", Json::Str("acme".into())),
            ("max_new_tokens", Json::Num(budget as f64)),
        ]))
        .unwrap();
    }
    // Both accepted; wait until both stream (order of lines across the
    // two pump threads is arbitrary, so classify by id).
    let mut accepted = 0;
    let mut streaming = std::collections::HashSet::new();
    while accepted < 2 || streaming.len() < 2 {
        let j = a.recv().unwrap();
        if j.get("accepted").is_some() {
            accepted += 1;
        } else if j.get("token").is_some() {
            streaming.insert(j.req_str("id").unwrap());
        }
    }

    // Admin bulk-cancel from a different connection.
    let mut admin = Client::connect(&addr).unwrap();
    admin.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    admin.admin_cancel_tenant("acme").unwrap();
    let ack = admin.recv().unwrap();
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(ack.req_usize("cancelled").unwrap(), 2);

    // Both of A's streams end with reason "cancelled".
    let mut reasons = std::collections::HashMap::new();
    while reasons.len() < 2 {
        let j = a.recv().unwrap();
        if j.get("done").is_some() {
            reasons.insert(j.req_str("id").unwrap(), j.req_str("reason").unwrap());
        }
    }
    assert_eq!(reasons.get("t1").map(String::as_str), Some("cancelled"));
    assert_eq!(reasons.get("t2").map(String::as_str), Some("cancelled"));

    // Unknown tenants cancel nothing; malformed admin is a structured
    // error.
    admin.admin_cancel_tenant("nobody").unwrap();
    assert_eq!(admin.recv().unwrap().req_usize("cancelled").unwrap(), 0);
    admin
        .send(&Json::obj(vec![("admin", Json::obj(vec![("reboot", Json::Bool(true))]))]))
        .unwrap();
    assert_eq!(admin.recv().unwrap().req_str("code").unwrap(), "bad_admin");
}

#[test]
fn stalled_reader_never_delays_other_connections() {
    // A slow client submits a long generation and then stops reading its
    // socket entirely; a fast client on another connection must still
    // stream all of its own work promptly. (The engine-side bounded
    // buffering itself — channel at configured capacity — is asserted
    // deterministically in the sim and property tests; over TCP the OS
    // socket buffers add slack ahead of the bounded channel.)
    let budget = 600;
    let (cfg, spec, prompt) = cancelable_workload(budget);
    let cfg = EngineConfig {
        stream_capacity: 8,
        ..cfg
    };
    for policy in [
        fdpp::config::BackpressurePolicy::PauseDecode,
        fdpp::config::BackpressurePolicy::DropSlow,
    ] {
        let addr = start_server_with(
            EngineConfig {
                backpressure: policy,
                ..cfg.clone()
            },
            spec,
        );
        let mut slow = Client::connect(&addr).unwrap();
        slow.send(&Json::obj(vec![
            ("id", Json::Str("slow".into())),
            ("prompt", Json::Str(prompt.clone())),
            ("max_new_tokens", Json::Num(budget as f64)),
        ]))
        .unwrap();
        // `slow` now never reads again (its lines pile into OS buffers,
        // then into the bounded channel, then backpressure applies).

        let mut fast = Client::connect(&addr).unwrap();
        fast.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let t0 = Instant::now();
        for i in 0..5 {
            let out = fast.generate(&format!("fast stream {i}"), 8);
            assert!(out.is_ok(), "fast stream must keep flowing: {out:?}");
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "fast client stalled behind a slow reader ({policy:?})"
        );
        // The engine still answers stats (liveness) and the fast work
        // all finished.
        let stats = fdpp::util::json::parse(&fast.stats().unwrap()).unwrap();
        assert!(stats.req_usize("requests_finished").unwrap() >= 5);
        drop(slow);
    }
}

#[test]
fn observability_surface_over_the_wire() {
    let cfg = EngineConfig {
        flight_recorder_capacity: 128,
        ..test_cfg()
    };
    let addr = start_server(cfg);
    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();

    // A finished generation's done line carries the span breakdown,
    // and the phases partition the request's total time exactly.
    c.send(&Json::obj(vec![
        ("id", Json::Str("obs-1".into())),
        ("prompt", Json::Str("observability probe".into())),
        ("max_new_tokens", Json::Num(4.0)),
    ]))
    .unwrap();
    let _global = read_accepted(&mut c, "obs-1");
    let done = loop {
        let j = c.recv().unwrap();
        if j.get("done").is_some() {
            break j;
        }
    };
    let spans = done.field("spans").expect("done line carries spans");
    let total = spans.req_usize("total_us").unwrap();
    let parts = spans.req_usize("queue_wait_us").unwrap()
        + spans.req_usize("prefill_us").unwrap()
        + spans.req_usize("decode_us").unwrap()
        + spans.req_usize("paused_us").unwrap();
    assert_eq!(parts, total, "phases partition the total: {}", spans.to_string());
    // The sim engine runs on its virtual clock: time demonstrably
    // passed between submission and the first token.
    assert!(spans.req_usize("ttft_us").unwrap() >= 1);

    // dump_flight round-trips over loopback: ring bookkeeping plus the
    // newest entries of the run we just made.
    let flight = c.dump_flight(16).unwrap();
    assert_eq!(flight.req_usize("capacity").unwrap(), 128);
    assert!(flight.req_usize("recorded").unwrap() >= 1);
    let entries = flight.req_arr("entries").unwrap();
    assert!(!entries.is_empty(), "the generation left flight entries");
    assert!(entries.len() <= 16);
    assert!(entries[0].get("what").and_then(Json::as_str).is_some());

    // Prometheus exposition renders the same stats snapshot as text.
    let text = c.stats_prometheus().unwrap();
    assert!(
        text.contains("# TYPE fdpp_tokens_generated gauge"),
        "gauges rendered: {text}"
    );
    assert!(text.contains("fdpp_step_us_count"), "histograms rendered");

    // A malformed dump_flight argument is a structured error and the
    // connection survives it.
    c.send(&Json::obj(vec![(
        "admin",
        Json::obj(vec![("dump_flight", Json::Str("nope".into()))]),
    )]))
    .unwrap();
    assert_eq!(c.recv().unwrap().req_str("code").unwrap(), "bad_admin");
    let flight = c.dump_flight(4).unwrap();
    assert!(flight.req_arr("entries").unwrap().len() <= 4);
}

#[test]
fn cancel_unknown_id_is_structured_error() {
    let addr = start_server(test_cfg());
    let mut c = Client::connect(&addr).unwrap();
    c.cancel("never-submitted").unwrap();
    let j = c.recv().unwrap();
    assert_eq!(j.req_str("code").unwrap(), "unknown_id");
    assert!(j.get("error").is_some());
}

#[test]
fn stop_sequence_over_the_wire() {
    // Self-selecting stop byte: run unconstrained locally, pick the
    // first printable generated byte, then ask the server to stop on it.
    let (prompt, full) = {
        let mut found = None;
        for salt in 0..64u32 {
            let prompt = format!("wire stop probe {salt}");
            let toks = local_generation(&prompt, 12);
            if toks.iter().any(|t| (32..127).contains(t)) {
                found = Some((prompt, toks));
                break;
            }
        }
        found.expect("some probe emits a printable byte")
    };
    let (idx, stop_tok) = full
        .iter()
        .enumerate()
        .find(|(_, &t)| (32..127).contains(&t))
        .unwrap();
    let stop_str = String::from_utf8(vec![*stop_tok as u8]).unwrap();

    let addr = start_server(test_cfg());
    let mut c = Client::connect(&addr).unwrap();
    c.send(&Json::obj(vec![
        ("id", Json::Str("s1".into())),
        ("prompt", Json::Str(prompt)),
        ("max_new_tokens", Json::Num(12.0)),
        ("stop", Json::Arr(vec![Json::Str(stop_str)])),
    ]))
    .unwrap();
    let _global = read_accepted(&mut c, "s1");
    let mut tokens = Vec::new();
    let done = loop {
        let j = c.recv().unwrap();
        if j.get("done").is_some() {
            break j;
        }
        tokens.push(j.req_usize("token").unwrap() as u32);
    };
    assert_eq!(done.req_str("reason").unwrap(), "stop");
    assert_eq!(tokens.len(), idx + 1, "stops exactly at the matched byte");
    assert_eq!(tokens[..], full[..idx + 1], "prefix is byte-identical");
}

#[test]
fn budget_clamped_to_engine_cap() {
    let cfg = EngineConfig {
        max_new_tokens: 5,
        ..test_cfg()
    };
    // Pick a prompt that would decode past the cap if unclamped.
    let (prompt, _) = long_running_prompt(5, 5);
    let addr = start_server(cfg);
    let mut c = Client::connect(&addr).unwrap();
    c.send(&Json::obj(vec![
        ("prompt", Json::Str(prompt)),
        ("max_new_tokens", Json::Num(10000.0)),
    ]))
    .unwrap();
    let done = loop {
        let j = c.recv().unwrap();
        if j.get("done").is_some() {
            break j;
        }
    };
    assert_eq!(
        done.req_usize("n").unwrap(),
        5,
        "10000 requested, engine cap 5: budget must clamp to exactly 5"
    );
}

#[test]
fn invalid_requests_get_structured_errors_and_connection_survives() {
    let addr = start_server(test_cfg());
    let mut c = Client::connect(&addr).unwrap();
    for (line, code) in [
        (r#"{"prompt":"p","temperature":1e999}"#, "bad_request"),
        (r#"{"max_new_tokens":4}"#, "bad_request"),
        (r#"{"prompt":"p","stop":[""]}"#, "bad_request"),
        ("this is not json", "bad_json"),
        (r#"{"admin":"reboot"}"#, "bad_admin"),
    ] {
        c.send_raw(line).unwrap();
        let j = c.recv().unwrap();
        assert_eq!(j.req_str("code").unwrap(), code, "for line {line}");
        assert!(j.get("error").is_some());
    }
    // The connection still serves valid work afterwards.
    let out = c.generate("still alive", 3).unwrap();
    let _ = out; // generation may legitimately decode to specials only
}

/// Bind port 0, spawn a sim-backed fleet behind the production accept
/// loop, and return the dialable address.
fn start_fleet_server(cfg: EngineConfig, fcfg: FleetConfig, spec: SimSpec) -> String {
    let vocab = spec.vocab;
    let max_new_cap = cfg.max_new_tokens;
    let handle = spawn_sim_fleet(cfg, fcfg, spec).expect("sim fleet starts");
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = serve_on(listener, handle, vocab, max_new_cap);
    });
    addr
}

#[test]
fn fleet_server_generates_and_reports_fleet_stats() {
    let fcfg = FleetConfig {
        n_replicas: 2,
        policy: RoutePolicy::CacheAware,
        ..FleetConfig::default()
    };
    let addr = start_fleet_server(test_cfg(), fcfg, SimSpec::default());
    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    // Generation through a fleet is the same wire protocol.
    c.generate("hello fleet", 8).unwrap();
    // `{"stats": true}` carries the fleet breakdown plus server fields.
    let stats = parse(&c.stats().unwrap()).unwrap();
    assert!(stats.get("registry_depth").is_some());
    let fleet = stats.field("fleet").expect("stats carry fleet object");
    assert_eq!(fleet.req_usize("replicas").unwrap(), 2);
    assert_eq!(fleet.req_str("policy").unwrap(), "cache_aware");
    // The fleet_stats admin verb returns the same snapshot shape.
    let fs = c.fleet_stats().unwrap();
    assert_eq!(fs.field("fleet").unwrap().req_usize("replicas_up").unwrap(), 2);
    let replicas = fs.field("replicas").expect("per-replica breakdown");
    let finished: usize = ["0", "1"]
        .iter()
        .map(|k| replicas.field(k).unwrap().req_usize("requests_finished").unwrap())
        .sum();
    assert_eq!(finished, 1, "exactly one replica served the request");
    for k in ["0", "1"] {
        assert_eq!(replicas.field(k).unwrap().req_str("health").unwrap(), "up");
    }
}

#[test]
fn fleet_admin_verbs_are_bad_admin_on_a_bare_engine() {
    let addr = start_server(test_cfg());
    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let err = c.drain_replica(0).unwrap_err();
    assert!(
        err.to_string().contains("does not support"),
        "bare engine rejects fleet verbs: {err}"
    );
    // The connection survives and still serves work.
    c.generate("still alive", 3).unwrap();
}

#[test]
fn kill_replica_over_the_wire_resubmits_mid_stream_work() {
    // Two long generations round-robin onto two replicas; killing
    // replica 1 mid-stream restarts its request on replica 0. The
    // victim's wire stream ends without a done line (its submitter's
    // channel died with the replica); the re-run is serviced by the
    // fleet and lands in the merged finish counters.
    let budget = 512;
    let (cfg, spec, prompt) = cancelable_workload(budget);
    let fcfg = FleetConfig {
        n_replicas: 2,
        policy: RoutePolicy::RoundRobin,
        ..FleetConfig::default()
    };
    let addr = start_fleet_server(cfg, fcfg, spec);
    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    for id in ["a", "b"] {
        c.send(&Json::obj(vec![
            ("id", Json::Str(id.into())),
            ("prompt", Json::Str(prompt.clone())),
            ("max_new_tokens", Json::Num(budget as f64)),
        ]))
        .unwrap();
    }
    c.send(&Json::obj(vec![(
        "admin",
        Json::obj(vec![("kill_replica", Json::Num(1.0))]),
    )]))
    .unwrap();
    // Acks, token lines, the kill reply, and "a"'s done line interleave
    // on the shared socket; collect until we have the latter two.
    let mut kill_reply = None;
    let mut done_a = None;
    while kill_reply.is_none() || done_a.is_none() {
        let j = c.recv().unwrap();
        if j.get("resubmitted").is_some() {
            kill_reply = Some(j);
        } else if j.get("done").is_some() {
            assert_eq!(j.req_str("id").unwrap(), "a", "only a's stream finishes");
            done_a = Some(j);
        }
    }
    let kill = kill_reply.unwrap();
    assert_eq!(kill.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(kill.req_usize("resubmitted").unwrap(), 1, "b was mid-stream");
    assert_eq!(done_a.unwrap().req_usize("n").unwrap(), budget);
    // The re-run finishes on the survivor: poll the merged counters.
    let mut c2 = Client::connect(&addr).unwrap();
    c2.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let t0 = Instant::now();
    loop {
        let fs = c2.fleet_stats().unwrap();
        if fs.req_usize("requests_finished").unwrap() >= 2 {
            assert_eq!(fs.field("fleet").unwrap().req_usize("resubmitted").unwrap(), 1);
            let dead = fs.field("replicas").unwrap().field("1").unwrap();
            assert_eq!(dead.req_str("health").unwrap(), "dead");
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "resubmitted request never finished: {}",
            fs.to_string()
        );
        thread::sleep(Duration::from_millis(10));
    }
}
