//! Backend equivalence: the same scripted worlds, three compute
//! backends, byte-identical behavior.
//!
//! `EngineCore<SimBackend>` derives logits from the KV bytes stored in
//! the paged cache; `EngineCore<StubBackend>` serves the same hash
//! model through different mechanics (token-by-token prefill
//! materialization, analytic logits recomputed from the token history).
//! Driving the full seeded scenario matrix through both and asserting
//! equal `ScenarioReport`s — including the fingerprint that folds every
//! `TraceEvent` and every drained token — proves two things at once:
//!
//! - the orchestration core treats backends uniformly (no sim-only or
//!   stub-only scheduling behavior), and
//! - the paged KV store faithfully round-trips what was written (the
//!   sim's stored-bytes digest equals the stub's from-first-principles
//!   digest on every logits row of every scenario).
//!
//! The third backend widens the matrix: `ShardedBackend<SimBackend>`
//! at M∈{1,2,4} lanes must produce the *same* reports again — sharding
//! is a pure partitioning, invisible to scheduling — and a per-lane
//! hook-trace lockstep pins the exact order the wrapper drives each
//! lane's join/leave/pause/resume bookkeeping.
//!
//! The grouped-decode matrix closes the loop: with
//! `EngineConfig::grouped_decode` enabled the sim backend reuses
//! shared-prefix attention compute per `DecodeGroup`, and every seed's
//! report must still equal the ungrouped baseline's byte for byte —
//! reuse is a pure compute optimization, never a behavior change.
//!
//! The chunked-decode matrix widens it once more: with
//! `EngineConfig::decode_chunk > 1` each decode step fuses several
//! token rounds behind one pass of the per-step policy work. On the
//! chunk-safe scenario family every chunk size must produce the same
//! `behavior_key` — every report field including the order-sensitive
//! fingerprint, with only the step count (pacing) free to shrink — and
//! the fleet/sharded/grouped wrappers must stay transparent under
//! chunking. On the fully adversarial family (step-indexed client
//! scripts, whose meaning legitimately shifts when the step axis
//! compresses) the five oracles and same-chunk reproducibility must
//! still hold.
//!
//! A divergence names the seed; replay it with
//! `cargo run --example simtest -- --seed N` (add `--shards M` for the
//! sharded run).

use fdpp::api::{GenRequest, InferenceEngine};
use fdpp::config::EngineConfig;
use fdpp::core::{EngineCore, StubEngine};
use fdpp::shard::{ShardHook, ShardedBackend};
use fdpp::simengine::{SimBackend, SimEngine, SimSpec};
use fdpp::simtest::{
    behavior_key, generate_scenario, run_scenario, run_scenario_chunked,
    run_scenario_chunked_adversarial, run_scenario_chunked_fleet, run_scenario_chunked_grouped,
    run_scenario_chunked_sharded, run_scenario_grouped, run_scenario_on, run_scenario_sharded,
    trace_fingerprint,
};
use fdpp::util::clock::Clock;

/// The same fixed matrix CI runs for the sim-only oracle pass.
const SEED_MATRIX: std::ops::RangeInclusive<u64> = 1..=24;

#[test]
fn seed_matrix_fingerprints_are_backend_identical() {
    let mut diverged = Vec::new();
    for seed in SEED_MATRIX {
        let scenario = generate_scenario(seed);
        let sim = run_scenario(seed).expect("sim backend passes oracles");
        let stub_engine =
            StubEngine::new(scenario.cfg.clone(), SimSpec::default()).expect("stub engine builds");
        let stub = run_scenario_on(&scenario, stub_engine).expect("stub backend passes oracles");
        if sim != stub {
            eprintln!(
                "seed {seed}: sim fp {:016x} != stub fp {:016x} ({sim:?} vs {stub:?})",
                sim.fingerprint, stub.fingerprint
            );
            diverged.push(seed);
        }
    }
    assert!(diverged.is_empty(), "diverging seeds: {diverged:?}");
}

/// A directed lockstep: step both engines side by side on an identical
/// workload and compare the raw trace streams step by step, so a
/// divergence reports the first differing step instead of only a
/// whole-run fingerprint mismatch.
#[test]
fn lockstep_traces_match_step_by_step() {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 32,
        max_new_tokens: 12,
        prefix_cache: true,
        stream_capacity: 64,
        ..EngineConfig::default()
    };
    let spec = SimSpec::default();
    let mut sim = SimEngine::new(cfg.clone(), spec).unwrap();
    let mut stub = StubEngine::new(cfg, spec).unwrap();
    sim.enable_trace();
    stub.enable_trace();

    let prompts = [
        "shared system preamble: alpha",
        "shared system preamble: beta",
        "shared system preamble: alpha", // prefix + dedup interplay
        "disjoint prompt",
    ];
    let mut sim_handles = Vec::new();
    let mut stub_handles = Vec::new();
    for p in prompts {
        let req = || GenRequest::text(p).max_new_tokens(8);
        sim_handles.push(sim.submit(req()).unwrap());
        stub_handles.push(stub.submit(req()).unwrap());
    }
    let mut step = 0;
    while !(sim.is_idle() && stub.is_idle()) {
        assert!(step < 2_000, "lockstep must terminate");
        if !sim.is_idle() {
            sim.step().unwrap();
        }
        if !stub.is_idle() {
            stub.step().unwrap();
        }
        let a = sim.take_trace();
        let b = stub.take_trace();
        assert_eq!(a, b, "trace diverged at step {step}");
        assert_eq!(trace_fingerprint(&a), trace_fingerprint(&b));
        step += 1;
    }
    for (sh, th) in sim_handles.iter().zip(stub_handles.iter()) {
        let (sim_toks, sim_fin) = sh.drain();
        let (stub_toks, stub_fin) = th.drain();
        assert_eq!(sim_toks, stub_toks, "token streams must be identical");
        assert_eq!(sim_fin, stub_fin, "finish records must be identical");
    }
    assert_eq!(
        sim.metrics.dedup_hits, stub.metrics.dedup_hits,
        "core-owned counters agree across backends"
    );
}

/// The widened matrix: every seed's report under the sharded sim
/// backend must equal the plain sim backend's byte for byte, at every
/// lane count — the "sharding is invisible to scheduling" headline.
#[test]
fn seed_matrix_fingerprints_are_shard_count_invariant() {
    let mut diverged = Vec::new();
    for seed in SEED_MATRIX {
        let baseline = run_scenario(seed).expect("sim backend passes oracles");
        for shards in [1usize, 2, 4] {
            let sharded =
                run_scenario_sharded(seed, shards).expect("sharded backend passes oracles");
            if baseline != sharded {
                eprintln!(
                    "seed {seed} M={shards}: sim fp {:016x} != sharded fp {:016x}",
                    baseline.fingerprint, sharded.fingerprint
                );
                diverged.push((seed, shards));
            }
        }
    }
    assert!(diverged.is_empty(), "diverging (seed, M): {diverged:?}");
}

/// Grouped decode reuses shared-prefix attention compute; it must
/// never change a scheduling decision or an output token. Every seed's
/// report with `grouped_decode` enabled must equal the ungrouped
/// baseline's byte for byte — fingerprint included.
#[test]
fn seed_matrix_fingerprints_are_grouping_invariant() {
    let mut diverged = Vec::new();
    for seed in SEED_MATRIX {
        let baseline = run_scenario(seed).expect("sim backend passes oracles");
        let grouped = run_scenario_grouped(seed).expect("grouped run passes oracles");
        if baseline != grouped {
            eprintln!(
                "seed {seed}: ungrouped fp {:016x} != grouped fp {:016x}",
                baseline.fingerprint, grouped.fingerprint
            );
            diverged.push(seed);
        }
    }
    assert!(diverged.is_empty(), "diverging seeds: {diverged:?}");
}

/// The chunked-decode differential matrix: on the chunk-safe scenario
/// family, every chunk size must reproduce the chunk-1 baseline's
/// behavior key exactly — same trace fingerprint, same token/lifecycle
/// counts — while never taking *more* engine steps. Chunking is an
/// orchestration amortization, never a behavior change.
#[test]
fn seed_matrix_behavior_is_chunk_invariant() {
    let mut diverged = Vec::new();
    for seed in SEED_MATRIX {
        let baseline = run_scenario_chunked(seed, 1).expect("chunk-1 baseline passes oracles");
        for chunk in [2usize, 4, 8] {
            let chunked = run_scenario_chunked(seed, chunk).expect("chunked run passes oracles");
            if behavior_key(&baseline) != behavior_key(&chunked) {
                eprintln!(
                    "seed {seed} chunk {chunk}: baseline fp {:016x} != chunked fp {:016x}",
                    baseline.fingerprint, chunked.fingerprint
                );
                diverged.push((seed, chunk));
            } else if chunked.steps > baseline.steps {
                eprintln!(
                    "seed {seed} chunk {chunk}: {} steps exceeds baseline {}",
                    chunked.steps, baseline.steps
                );
                diverged.push((seed, chunk));
            }
        }
    }
    assert!(diverged.is_empty(), "diverging (seed, chunk): {diverged:?}");
}

/// Chunking composed with every wrapper: grouped decode on the same
/// core, a sharded backend underneath, a fleet layer on top. Each
/// composition must reproduce the bare chunk-1 baseline's behavior key
/// for every seed — the wrappers proved themselves transparent to the
/// unchunked step loop, and they must stay transparent to the fused
/// one.
#[test]
fn seed_matrix_chunked_compositions_stay_transparent() {
    let mut diverged = Vec::new();
    for seed in SEED_MATRIX {
        let baseline = run_scenario_chunked(seed, 1).expect("chunk-1 baseline passes oracles");
        let key = behavior_key(&baseline);
        for chunk in [2usize, 4, 8] {
            let grouped =
                run_scenario_chunked_grouped(seed, chunk).expect("grouped run passes oracles");
            if behavior_key(&grouped) != key {
                diverged.push((seed, chunk, "grouped"));
            }
        }
        for chunk in [2usize, 4] {
            let sharded = run_scenario_chunked_sharded(seed, chunk, 2)
                .expect("sharded run passes oracles");
            if behavior_key(&sharded) != key {
                diverged.push((seed, chunk, "sharded"));
            }
            let fleet =
                run_scenario_chunked_fleet(seed, chunk, 1).expect("fleet run passes oracles");
            if behavior_key(&fleet) != key {
                diverged.push((seed, chunk, "fleet"));
            }
        }
    }
    assert!(
        diverged.is_empty(),
        "diverging (seed, chunk, composition): {diverged:?}"
    );
}

/// The adversarial half of the chunk matrix: slow readers, stalls,
/// disconnects, and step-indexed cancels — behaviors chunking
/// legitimately re-times. What must survive: all five oracles, and
/// byte-identical reproduction at the same chunk value.
#[test]
fn chunked_adversarial_matrix_passes_oracles_and_reproduces() {
    for seed in SEED_MATRIX {
        for chunk in [2usize, 4, 8] {
            let a = run_scenario_chunked_adversarial(seed, chunk)
                .expect("adversarial chunked run passes oracles");
            let b = run_scenario_chunked_adversarial(seed, chunk)
                .expect("adversarial chunked run passes oracles");
            assert_eq!(a, b, "seed {seed} chunk {chunk} must reproduce exactly");
        }
    }
}

/// Step a sharded engine in lockstep with a plain sim engine under a
/// backpressure-heavy workload (tiny stream credit, periodic drains, so
/// sequences park and resume), asserting identical core traces every
/// step — then pin the per-lane hook order: the wrapper must drive
/// every hook as one whole group of M events, lanes ascending, and the
/// groups must include pauses and resumes.
#[test]
fn sharded_hook_trace_is_per_lane_lockstep() {
    const M: usize = 3;
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 64,
        max_new_tokens: 12,
        prefix_cache: true,
        stream_capacity: 2,
        ..EngineConfig::default()
    };
    let spec = SimSpec::default();
    let mut sim = SimEngine::new(cfg.clone(), spec).unwrap();
    let mut sharded = EngineCore::with_backend(
        ShardedBackend::new(SimBackend::new(spec), M),
        cfg,
        Clock::manual(),
    )
    .unwrap();
    sim.enable_trace();
    sharded.enable_trace();
    sharded.backend().enable_hook_trace();

    let prompts = [
        "lockstep lane probe: alpha",
        "lockstep lane probe: beta",
        "lockstep lane probe: gamma",
        "lockstep lane probe: delta",
    ];
    let mut sim_handles = Vec::new();
    let mut sharded_handles = Vec::new();
    for p in prompts {
        let req = || GenRequest::text(p).max_new_tokens(10);
        sim_handles.push(sim.submit(req()).unwrap());
        sharded_handles.push(sharded.submit(req()).unwrap());
    }
    let mut step = 0;
    while !(sim.is_idle() && sharded.is_idle()) {
        assert!(step < 4_000, "lockstep must terminate");
        if !sim.is_idle() {
            sim.step().unwrap();
        }
        if !sharded.is_idle() {
            sharded.step().unwrap();
        }
        // Drain only every fourth step: with credit 2 the streams fill
        // in between, forcing pause/resume churn on both engines.
        if step % 4 == 3 {
            for h in &sim_handles {
                while h.events.try_recv().is_ok() {}
            }
            for h in &sharded_handles {
                while h.events.try_recv().is_ok() {}
            }
        }
        let a = sim.take_trace();
        let b = sharded.take_trace();
        assert_eq!(a, b, "trace diverged at step {step}");
        assert_eq!(trace_fingerprint(&a), trace_fingerprint(&b));
        step += 1;
    }

    let hooks = sharded.backend().take_hook_trace();
    assert!(!hooks.is_empty(), "the run must have driven hooks");
    assert_eq!(hooks.len() % M, 0, "events come in whole per-lane groups");
    let mut saw_pause = false;
    let mut saw_resume = false;
    let mut i = 0;
    while i < hooks.len() {
        assert_eq!(hooks[i].shard(), 0, "group at {i} must start at lane 0");
        for s in 0..M {
            assert_eq!(
                hooks[i + s],
                hooks[i].at_shard(s),
                "group at {i} must replicate one hook across lanes in order"
            );
        }
        saw_pause |= matches!(hooks[i], ShardHook::Pause { .. });
        saw_resume |= matches!(hooks[i], ShardHook::Resume { .. });
        i += M;
    }
    assert!(saw_pause, "backpressure must park at least one sequence");
    assert!(saw_resume, "parked sequences must resume");
}
