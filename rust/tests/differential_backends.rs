//! Backend equivalence: the same scripted worlds, two compute
//! backends, byte-identical behavior.
//!
//! `EngineCore<SimBackend>` derives logits from the KV bytes stored in
//! the paged cache; `EngineCore<StubBackend>` serves the same hash
//! model through different mechanics (token-by-token prefill
//! materialization, analytic logits recomputed from the token history).
//! Driving the full seeded scenario matrix through both and asserting
//! equal `ScenarioReport`s — including the fingerprint that folds every
//! `TraceEvent` and every drained token — proves two things at once:
//!
//! - the orchestration core treats backends uniformly (no sim-only or
//!   stub-only scheduling behavior), and
//! - the paged KV store faithfully round-trips what was written (the
//!   sim's stored-bytes digest equals the stub's from-first-principles
//!   digest on every logits row of every scenario).
//!
//! A divergence names the seed; replay it with
//! `cargo run --example simtest -- --seed N`.

use fdpp::api::{GenRequest, InferenceEngine};
use fdpp::config::EngineConfig;
use fdpp::core::StubEngine;
use fdpp::simengine::{SimEngine, SimSpec};
use fdpp::simtest::{generate_scenario, run_scenario, run_scenario_on, trace_fingerprint};

/// The same fixed matrix CI runs for the sim-only oracle pass.
const SEED_MATRIX: std::ops::RangeInclusive<u64> = 1..=24;

#[test]
fn seed_matrix_fingerprints_are_backend_identical() {
    let mut diverged = Vec::new();
    for seed in SEED_MATRIX {
        let scenario = generate_scenario(seed);
        let sim = run_scenario(seed).expect("sim backend passes oracles");
        let stub_engine =
            StubEngine::new(scenario.cfg.clone(), SimSpec::default()).expect("stub engine builds");
        let stub = run_scenario_on(&scenario, stub_engine).expect("stub backend passes oracles");
        if sim != stub {
            eprintln!(
                "seed {seed}: sim fp {:016x} != stub fp {:016x} ({sim:?} vs {stub:?})",
                sim.fingerprint, stub.fingerprint
            );
            diverged.push(seed);
        }
    }
    assert!(diverged.is_empty(), "diverging seeds: {diverged:?}");
}

/// A directed lockstep: step both engines side by side on an identical
/// workload and compare the raw trace streams step by step, so a
/// divergence reports the first differing step instead of only a
/// whole-run fingerprint mismatch.
#[test]
fn lockstep_traces_match_step_by_step() {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 32,
        max_new_tokens: 12,
        prefix_cache: true,
        stream_capacity: 64,
        ..EngineConfig::default()
    };
    let spec = SimSpec::default();
    let mut sim = SimEngine::new(cfg.clone(), spec).unwrap();
    let mut stub = StubEngine::new(cfg, spec).unwrap();
    sim.enable_trace();
    stub.enable_trace();

    let prompts = [
        "shared system preamble: alpha",
        "shared system preamble: beta",
        "shared system preamble: alpha", // prefix + dedup interplay
        "disjoint prompt",
    ];
    let mut sim_handles = Vec::new();
    let mut stub_handles = Vec::new();
    for p in prompts {
        let req = || GenRequest::text(p).max_new_tokens(8);
        sim_handles.push(sim.submit(req()).unwrap());
        stub_handles.push(stub.submit(req()).unwrap());
    }
    let mut step = 0;
    while !(sim.is_idle() && stub.is_idle()) {
        assert!(step < 2_000, "lockstep must terminate");
        if !sim.is_idle() {
            sim.step().unwrap();
        }
        if !stub.is_idle() {
            stub.step().unwrap();
        }
        let a = sim.take_trace();
        let b = stub.take_trace();
        assert_eq!(a, b, "trace diverged at step {step}");
        assert_eq!(trace_fingerprint(&a), trace_fingerprint(&b));
        step += 1;
    }
    for (sh, th) in sim_handles.iter().zip(stub_handles.iter()) {
        let (sim_toks, sim_fin) = sh.drain();
        let (stub_toks, stub_fin) = th.drain();
        assert_eq!(sim_toks, stub_toks, "token streams must be identical");
        assert_eq!(sim_fin, stub_fin, "finish records must be identical");
    }
    assert_eq!(
        sim.metrics.dedup_hits, stub.metrics.dedup_hits,
        "core-owned counters agree across backends"
    );
}
