//! Property test for the sharded backend's headline invariant: at any
//! point in any run, concatenating the per-shard KV slices reconstructs
//! the unsharded dense state *exactly*.
//!
//! A seeded driver throws a random world at
//! `EngineCore<ShardedBackend<SimBackend>>` — staggered arrivals,
//! random cancels, clients that drain at different periods (so streams
//! fill, park, and resume), and a KV pool tight enough to preempt —
//! and after **every** step asks the wrapper to verify that every
//! mirrored sequence's per-shard slices equal the paged store element
//! for element ([`fdpp::shard::ShardedBackend::verify_sharding`]), and
//! that every live sequence holding KV is mirrored at all.
//!
//! At the end of each run the collective counters must match the
//! analytic formula for the observed batch shapes: one all-gather and
//! one all-reduce per result row (prefills + decode rows), with byte
//! volumes `(M-1)·E·4` and `2·(M-1)·V·4` per row — and exactly zero
//! at M=1.

use fdpp::api::{GenRequest, InferenceEngine, SubmissionHandle};
use fdpp::config::EngineConfig;
use fdpp::core::EngineCore;
use fdpp::shard::ShardedBackend;
use fdpp::simengine::{SimBackend, SimSpec};
use fdpp::util::clock::Clock;
use fdpp::util::rng::Rng;

struct Client {
    arrive: usize,
    cancel_at: Option<usize>,
    drain_mod: usize,
    prompt: String,
    budget: usize,
    handle: Option<SubmissionHandle>,
    submitted: bool,
}

fn run_reconstruction(seed: u64, shards: usize) {
    let mut rng = Rng::seed_from_u64(seed);
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 64,
        max_new_tokens: 12,
        max_running: 4,
        prefix_cache: true,
        stream_capacity: 4,
        seed,
        ..EngineConfig::default()
    };
    let mut e = EngineCore::with_backend(
        ShardedBackend::new(SimBackend::new(SimSpec::default()), shards),
        cfg,
        Clock::manual(),
    )
    .unwrap();

    let n_clients = 8 + rng.gen_range(0, 9);
    let mut clients: Vec<Client> = (0..n_clients)
        .map(|i| {
            let words = 1 + rng.gen_range(0, 10);
            let mut prompt = format!("prop shard {seed} client {i}");
            for w in 0..words {
                prompt.push_str(&format!(" word{w}"));
            }
            Client {
                arrive: rng.gen_range(0, 20),
                cancel_at: if rng.gen_range(0, 4) == 0 {
                    Some(rng.gen_range(0, 40))
                } else {
                    None
                },
                drain_mod: 1 + rng.gen_range(0, 4),
                prompt,
                budget: 2 + rng.gen_range(0, 11),
                handle: None,
                submitted: false,
            }
        })
        .collect();

    let mut step = 0usize;
    loop {
        assert!(step < 5_000, "seed {seed} M={shards}: prop driver wedged");
        for c in clients.iter_mut() {
            if !c.submitted && c.arrive <= step {
                let req = GenRequest::text(&c.prompt).max_new_tokens(c.budget);
                c.handle = Some(e.submit(req).unwrap());
                c.submitted = true;
            }
        }
        for c in clients.iter() {
            if let Some(h) = &c.handle {
                if c.cancel_at == Some(step) {
                    let _ = e.cancel(h.id);
                }
                // Every client eventually drains (drain_mod <= 4), so
                // parked streams always resume and the run terminates.
                if step % c.drain_mod == 0 {
                    while h.events.try_recv().is_ok() {}
                }
            }
        }
        if !e.is_idle() {
            e.step().unwrap();
        }

        // The reconstruction oracle, after every step.
        if let Err(msg) = e.backend().verify_sharding(e.kv()) {
            panic!("seed {seed} M={shards} step {step}: {msg}");
        }
        for ls in e.audit().live {
            if e.kv().seq_len(ls.id).is_some() {
                assert!(
                    e.backend().is_mirrored(ls.id),
                    "seed {seed} M={shards} step {step}: live seq {} has KV but no mirror",
                    ls.id
                );
            }
        }

        let all_submitted = clients.iter().all(|c| c.submitted);
        if all_submitted && e.is_idle() {
            break;
        }
        step += 1;
    }

    // Collective counts are an exact function of the observed batch
    // shapes: one all-gather + one all-reduce per result row.
    let m = &e.metrics;
    let sm = e.backend().shard_metrics();
    let rows = m.prefill_steps + m.decode_rows;
    assert!(rows > 0, "seed {seed} M={shards}: the run must do work");
    let expected = if shards > 1 { rows } else { 0 };
    assert_eq!(
        sm.allgather_ops, expected,
        "seed {seed} M={shards}: all-gather count"
    );
    assert_eq!(
        sm.allreduce_ops, expected,
        "seed {seed} M={shards}: all-reduce count"
    );
    let te = e.geometry().token_elems() as u64;
    let vocab = SimSpec::default().vocab as u64;
    let lanes = shards as u64;
    if shards > 1 {
        assert_eq!(
            sm.allgather_bytes,
            expected * (lanes - 1) * te * 4,
            "seed {seed} M={shards}: all-gather bytes"
        );
        assert_eq!(
            sm.allreduce_bytes,
            expected * 2 * (lanes - 1) * vocab * 4,
            "seed {seed} M={shards}: all-reduce bytes"
        );
    } else {
        assert_eq!(sm.allgather_bytes, 0, "M=1 moves nothing");
        assert_eq!(sm.allreduce_bytes, 0, "M=1 moves nothing");
    }
}

#[test]
fn per_shard_slices_reconstruct_dense_state_for_random_worlds() {
    for seed in 101u64..=112 {
        let shards = 1 + (seed as usize % 5);
        run_reconstruction(seed, shards);
    }
    // One deliberately over-partitioned run: more lanes than heads.
    run_reconstruction(131, 8);
}
