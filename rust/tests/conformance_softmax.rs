//! Conformance suite for the §3 asynchronized softmax with a unified
//! max value: `softmax_unified` under a `SoftmaxInputStats`-derived
//! policy must match the synchronized two-pass reference within 1e-6
//! relative error across adversarial input ranges; an OPT-6.7B-style
//! wide-range distribution must flip the policy to the synchronized
//! path; and the window edges at `phi + a` / `phi + b` behave exactly
//! as the kernel's recompute rule specifies.
//!
//! Error metric: per element, `|unified - reference|` must be within
//! `1e-6 * max_j(reference_j)` (row-max-relative, the standard kernel
//! conformance metric), and elements carrying non-negligible mass
//! (>= 1e-3 of the row max) must also match to 1e-6 *elementwise*
//! relative error.

use fdpp::softmaxstats::{
    derive_policy, paper_figure5_ranges, softmax_reference, softmax_unified, SoftmaxInputStats,
    UnifiedMaxPolicy, SAFE_A, SAFE_B,
};
use fdpp::util::rng::Rng;

const REL_TOL: f64 = 1e-6;

fn stats_from_values(xs: &[f32]) -> SoftmaxInputStats {
    let mut s = SoftmaxInputStats::new();
    s.extend(xs);
    s
}

/// Assert the conformance error metric between a unified row and the
/// two-pass reference.
fn assert_conformant(xs: &[f32], policy: &UnifiedMaxPolicy, ctx: &str) -> bool {
    let got = softmax_unified(xs, policy);
    let want = softmax_reference(xs);
    assert_eq!(got.probs.len(), want.len(), "{ctx}: length");
    let sum: f64 = got.probs.iter().sum();
    assert!((sum - 1.0).abs() < 1e-9, "{ctx}: sum {sum} != 1");
    let row_max = want.iter().cloned().fold(0.0f64, f64::max);
    for (i, (u, r)) in got.probs.iter().zip(&want).enumerate() {
        assert!(
            (u - r).abs() <= REL_TOL * row_max,
            "{ctx}: element {i}: unified {u} vs reference {r} (row max {row_max})"
        );
        if *r >= 1e-3 * row_max {
            assert!(
                (u - r).abs() <= REL_TOL * r,
                "{ctx}: element {i} carries mass: relative error {} > {REL_TOL}",
                (u - r).abs() / r
            );
        }
    }
    got.fell_back
}

/// Uniform row sampled inside [lo, hi].
fn sample_row(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gen_f32(lo, hi)).collect()
}

#[test]
fn unified_matches_reference_across_adversarial_ranges() {
    // Per-range rows: Llama-style, narrow, shifted-far-negative,
    // shifted-positive, near-degenerate. The policy is derived from
    // the same distribution the rows are drawn from (the paper's
    // offline-statistics flow).
    let ranges: [(f32, f32); 5] = [
        (-16.8, 6.5),  // Llama2-7B (Figure 5)
        (-1.0, 1.0),   // narrow
        (-80.0, -60.0), // far negative: phi re-centers
        (40.0, 55.0),  // large positive: phi re-centers
        (3.14, 3.14),  // degenerate constant row
    ];
    let mut rng = Rng::seed_from_u64(0x50F7_3A81);
    for (lo, hi) in ranges {
        let calib = sample_row(&mut rng, 4096, lo, hi);
        let policy = derive_policy(&stats_from_values(&calib));
        assert!(policy.enabled, "range [{lo}, {hi}] must enable the path");
        let mut fallbacks = 0usize;
        let rows = 50;
        for r in 0..rows {
            let n = 16 + 61 * r % 1024;
            let xs = sample_row(&mut rng, n.max(2), lo, hi);
            if assert_conformant(&xs, &policy, &format!("range [{lo},{hi}] row {r}")) {
                fallbacks += 1;
            }
        }
        // In-distribution rows stay on the asynchronized path: the
        // paper's point is that recompute is rare.
        assert!(
            fallbacks * 100 <= rows,
            "range [{lo}, {hi}]: {fallbacks}/{rows} rows fell back"
        );
    }
}

#[test]
fn wide_range_distribution_forces_synchronized_mode() {
    // OPT-6.7B rule: the observed range cannot fit the safe window, so
    // the stats-driven policy disables the asynchronized path and every
    // row goes two-pass — bit-identical to the reference.
    let mut rng = Rng::seed_from_u64(0x0B7_6B);
    let calib = sample_row(&mut rng, 4096, -60.0, 30.0);
    let policy = derive_policy(&stats_from_values(&calib));
    assert!(!policy.enabled, "wide range must disable unified max");
    for r in 0..20 {
        let xs = sample_row(&mut rng, 512, -60.0, 30.0);
        let got = softmax_unified(&xs, &policy);
        assert!(got.fell_back, "row {r}: disabled policy must fall back");
        assert_eq!(got.probs, softmax_reference(&xs), "row {r}: exact match");
    }
    // And the published Figure 5 ranges reproduce the paper's
    // per-model enable/disable decisions.
    for (name, lo, hi) in paper_figure5_ranges() {
        let calib = sample_row(&mut rng, 2048, lo as f32, hi as f32);
        let p = derive_policy(&stats_from_values(&calib));
        assert_eq!(p.enabled, name != "opt-6.7b", "{name}");
    }
}

#[test]
fn outlier_above_window_triggers_recompute_and_stays_conformant() {
    // An enabled policy fed a row with one element past phi + b: the
    // kernel must take the synchronized recompute and still match the
    // reference (which it *is* in that branch).
    let mut rng = Rng::seed_from_u64(0xE0_17);
    let calib = sample_row(&mut rng, 4096, -16.8, 6.5);
    let policy = derive_policy(&stats_from_values(&calib));
    assert!(policy.enabled);
    let mut xs = sample_row(&mut rng, 256, -16.8, 6.5);
    xs[137] = (policy.phi + policy.b) as f32 + 5.0;
    let fell_back = assert_conformant(&xs, &policy, "outlier row");
    assert!(fell_back, "outlier past phi+b must force the fallback");
}

#[test]
fn window_edges_at_phi_plus_a_and_phi_plus_b() {
    // Exact-window policy (phi = 0) so the edge arithmetic is exact.
    let policy = UnifiedMaxPolicy {
        enabled: true,
        phi: 0.0,
        a: SAFE_A,
        b: SAFE_B,
        expected_recompute_rate: 0.0,
    };
    // At the top edge: included, asynchronized, conformant.
    let xs = vec![0.0f32, 1.0, SAFE_B as f32];
    assert!(!assert_conformant(&xs, &policy, "top edge"));
    // Past the top edge: recompute.
    let xs = vec![0.0f32, SAFE_B as f32 + f32::EPSILON + 1.0];
    assert!(softmax_unified(&xs, &policy).fell_back);
    // At the bottom edge: included (e^a, denormal-adjacent but exact).
    let xs = vec![0.0f32, SAFE_A as f32];
    assert!(!assert_conformant(&xs, &policy, "bottom edge"));
    // Below the bottom edge: flushed to zero — conformant under the
    // row-max-relative metric because the true mass is ~e^a ~ 1e-11.
    let xs = vec![0.0f32, SAFE_A as f32 - 20.0];
    let got = softmax_unified(&xs, &policy);
    assert!(!got.fell_back, "underflow must not force a recompute");
    assert_eq!(got.probs[1], 0.0);
    assert!(!assert_conformant(&xs, &policy, "below bottom edge"));
}

#[test]
fn unified_softmax_is_deterministic() {
    // Same inputs, same policy, byte-identical outputs — the property
    // the simulation harness relies on for seed replay.
    let mut rng = Rng::seed_from_u64(7);
    let calib = sample_row(&mut rng, 1024, -10.0, 5.0);
    let policy = derive_policy(&stats_from_values(&calib));
    let xs = sample_row(&mut rng, 333, -10.0, 5.0);
    let a = softmax_unified(&xs, &policy);
    let b = softmax_unified(&xs, &policy);
    assert_eq!(a, b);
}
