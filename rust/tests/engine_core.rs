//! Targeted scenarios for the serving core's own features: the
//! cross-request dedup of concurrent identical cold prompts, the
//! per-tenant concurrency quota, and the production trace/audit surface
//! in the stats snapshot.

use fdpp::api::{FinishReason, GenRequest, InferenceEngine};
use fdpp::config::EngineConfig;
use fdpp::simengine::{SimEngine, SimSpec, TraceEvent};
use fdpp::util::json::Json;

fn cfg() -> EngineConfig {
    EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 128,
        max_new_tokens: 16,
        prefix_cache: true,
        stream_capacity: 64,
        ..EngineConfig::default()
    }
}

/// A prompt long enough that its reusable prefix spans whole 8-token
/// blocks, whose greedy generation runs at least `min_tokens` (the hash
/// model is deterministic, so this is a stable selection).
fn probe_prompt(tag: &str, min_tokens: usize) -> String {
    for salt in 0..64u32 {
        let p = format!("{tag} shared prompt probe {salt:02}!!");
        let mut e = SimEngine::new(cfg(), SimSpec::default()).unwrap();
        let h = e.submit(GenRequest::text(&p).max_new_tokens(12)).unwrap();
        e.run_to_completion().unwrap();
        if h.drain().0.len() >= min_tokens {
            return p;
        }
    }
    panic!("no probe prompt generates {min_tokens}+ tokens");
}

// ---------------------------------------------------------------------
// Cross-request dedup
// ---------------------------------------------------------------------

#[test]
fn concurrent_identical_cold_prompts_dedup_instead_of_racing() {
    let prompt = probe_prompt("cold", 4);
    let mut e = SimEngine::new(cfg(), SimSpec::default()).unwrap();
    e.enable_trace();
    let a = e.submit(GenRequest::text(&prompt).max_new_tokens(6)).unwrap();
    let b = e.submit(GenRequest::text(&prompt).max_new_tokens(6)).unwrap();
    e.run_to_completion().unwrap();

    assert_eq!(
        e.metrics.dedup_hits, 1,
        "the second admission must wait for the in-flight twin, once"
    );
    let (ta, fa) = a.drain();
    let (tb, fb) = b.drain();
    assert_eq!(ta, tb, "identical prompts generate identical tokens");
    let ua = fa.expect("first request finishes").1;
    let ub = fb.expect("second request finishes").1;
    assert_eq!(ua.cached_prompt_tokens, 0, "the holder prefills cold");
    assert!(
        ub.cached_prompt_tokens >= 8,
        "the waiter shares the holder's registered blocks: {ub:?}"
    );
    assert_eq!(e.metrics.prefix_hits, 1, "one cache hit: the waiter");
    // The trace shows the waiter admitted *after* the holder finished.
    let trace = e.take_trace();
    let holder_finish = trace
        .iter()
        .position(|ev| matches!(ev, TraceEvent::Finished { id, .. } if *id == a.id))
        .expect("holder finish in trace");
    let waiter_admit = trace
        .iter()
        .position(|ev| matches!(ev, TraceEvent::Admitted { id, .. } if *id == b.id))
        .expect("waiter admission in trace");
    assert!(
        holder_finish < waiter_admit,
        "waiter admission must come after the holder's retirement"
    );
}

#[test]
fn dedup_wait_does_not_starve_other_queued_requests() {
    // A (holder, long budget), B (identical prompt, defers), C (distinct
    // prompt). B yields its queue slot while waiting, so C must admit
    // while A is still decoding — well before A's retirement unblocks B.
    let prompt = probe_prompt("hol", 8);
    let mut e = SimEngine::new(cfg(), SimSpec::default()).unwrap();
    e.enable_trace();
    let a = e.submit(GenRequest::text(&prompt).max_new_tokens(12)).unwrap();
    let b = e.submit(GenRequest::text(&prompt).max_new_tokens(4)).unwrap();
    let c = e.submit(GenRequest::text("a distinct prompt!").max_new_tokens(4)).unwrap();
    e.run_to_completion().unwrap();
    let trace = e.take_trace();
    let admit_of = |id| {
        trace
            .iter()
            .position(|ev| matches!(ev, TraceEvent::Admitted { id: x, .. } if *x == id))
            .expect("admission in trace")
    };
    let a_finish = trace
        .iter()
        .position(|ev| matches!(ev, TraceEvent::Finished { id, .. } if *id == a.id))
        .expect("holder finish in trace");
    assert!(
        admit_of(c.id) < a_finish,
        "the distinct prompt must admit while the waiter defers"
    );
    assert!(a_finish < admit_of(b.id), "the waiter still waits for the holder");
    assert_eq!(e.metrics.dedup_hits, 1);
}

#[test]
fn dedup_does_not_delay_distinct_or_cached_prompts() {
    // Distinct prompts: no dedup interaction.
    let mut e = SimEngine::new(cfg(), SimSpec::default()).unwrap();
    let one = GenRequest::text("prompt one, long enough!!").max_new_tokens(4);
    let two = GenRequest::text("prompt two, long enough!!").max_new_tokens(4);
    let _a = e.submit(one).unwrap();
    let _b = e.submit(two).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.dedup_hits, 0);

    // A prompt already served by the cache admits immediately even with
    // an identical request in flight (nothing left to dedup).
    let prompt = probe_prompt("warm", 4);
    let mut e = SimEngine::new(cfg(), SimSpec::default()).unwrap();
    let _warm = e.submit(GenRequest::text(&prompt).max_new_tokens(4)).unwrap();
    e.run_to_completion().unwrap();
    let _c = e.submit(GenRequest::text(&prompt).max_new_tokens(4)).unwrap();
    let _d = e.submit(GenRequest::text(&prompt).max_new_tokens(4)).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(
        e.metrics.dedup_hits, 0,
        "cached prompts never wait on the in-flight table"
    );
    assert!(e.metrics.prefix_hits >= 2);
}

#[test]
fn dedup_is_disabled_without_the_prefix_cache() {
    // With no cache there is nothing to share, so identical prompts
    // race (the pre-dedup behavior) rather than serialize.
    let prompt = probe_prompt("race", 4);
    let mut e = SimEngine::new(
        EngineConfig {
            prefix_cache: false,
            ..cfg()
        },
        SimSpec::default(),
    )
    .unwrap();
    let a = e.submit(GenRequest::text(&prompt).max_new_tokens(4)).unwrap();
    let b = e.submit(GenRequest::text(&prompt).max_new_tokens(4)).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.dedup_hits, 0);
    assert_eq!(a.drain().0, b.drain().0, "outputs still identical");
}

// ---------------------------------------------------------------------
// Per-tenant concurrency quota
// ---------------------------------------------------------------------

#[test]
fn tenant_quota_rejects_structured_and_releases_on_finish() {
    let mut e = SimEngine::new(
        EngineConfig {
            tenant_max_inflight: 1,
            ..cfg()
        },
        SimSpec::default(),
    )
    .unwrap();
    let first = GenRequest::text("acme request one").tenant("acme").max_new_tokens(4);
    let _a = e.submit(first).unwrap();
    let second = GenRequest::text("acme request two").tenant("acme").max_new_tokens(4);
    let err = e.submit(second).expect_err("second acme request exceeds the quota");
    assert_eq!(err.wire_code(), "quota_exceeded");
    assert!(err.to_string().contains("acme"), "names the tenant: {err}");
    assert_eq!(e.metrics.quota_rejections, 1);

    // Other tenants are unaffected.
    let globex = GenRequest::text("globex request").tenant("globex").max_new_tokens(4);
    let _b = e.submit(globex).unwrap();
    // The empty tenant normalizes to "default" and has its own budget.
    let _c = e.submit(GenRequest::text("anonymous request").max_new_tokens(4)).unwrap();
    let err = e
        .submit(GenRequest::text("anonymous request two").max_new_tokens(4))
        .expect_err("default tenant is quota'd too");
    assert_eq!(err.wire_code(), "quota_exceeded");

    // Finishing releases the slot.
    e.run_to_completion().unwrap();
    let third = GenRequest::text("acme request three").tenant("acme").max_new_tokens(4);
    let _d = e.submit(third).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.quota_rejections, 2);
    assert!(e.is_idle());
}

#[test]
fn tenant_quota_counts_queued_running_and_paused() {
    // Quota 2: one parked (undrained, 1-slot stream) + one queued fills
    // it; a third submission is rejected until a slot frees.
    let prompt = probe_prompt("park", 4);
    let mut e = SimEngine::new(
        EngineConfig {
            tenant_max_inflight: 2,
            stream_capacity: 1,
            ..cfg()
        },
        SimSpec::default(),
    )
    .unwrap();
    let parked = e
        .submit(GenRequest::text(&prompt).tenant("t").max_new_tokens(12))
        .unwrap();
    for _ in 0..6 {
        e.step().unwrap();
    }
    assert_eq!(e.paused(), 1, "undrained 1-slot stream parks its request");
    let queued = e
        .submit(GenRequest::text("waits in the queue").tenant("t").max_new_tokens(4))
        .unwrap();
    let err = e
        .submit(GenRequest::text("over quota").tenant("t").max_new_tokens(4))
        .expect_err("paused + queued fill the quota");
    assert_eq!(err.wire_code(), "quota_exceeded");
    // Cancel the parked request: the slot frees immediately.
    assert!(e.cancel(parked.id).unwrap());
    let ok = e
        .submit(GenRequest::text("fits again").tenant("t").max_new_tokens(4))
        .unwrap();
    // Drain while stepping (1-slot streams park undrained requests).
    let mut steps = 0;
    while !e.is_idle() {
        e.step().unwrap();
        queued.drain();
        ok.drain();
        steps += 1;
        assert!(steps < 1_000, "remaining requests must finish");
    }
    assert_eq!(e.metrics.quota_rejections, 1);
}

#[test]
fn zero_quota_means_unlimited() {
    let mut e = SimEngine::new(cfg(), SimSpec::default()).unwrap();
    for i in 0..8 {
        let req = GenRequest::text(format!("req {i}")).tenant("t").max_new_tokens(2);
        e.submit(req).unwrap();
    }
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.quota_rejections, 0);
    assert_eq!(e.metrics.requests_finished, 8);
}

// ---------------------------------------------------------------------
// Stats expose the audit surface (production sees what simtest sees)
// ---------------------------------------------------------------------

#[test]
fn stats_surface_audit_verdict_and_trace_enablement() {
    let mut e = SimEngine::new(cfg(), SimSpec::default()).unwrap();
    let stats = e.stats_json();
    assert_eq!(stats.get("kv_refcount_ok").and_then(Json::as_bool), Some(true));
    assert_eq!(stats.get("blocks_leaked").and_then(Json::as_usize), Some(0));
    assert_eq!(
        stats.get("trace_enabled").and_then(Json::as_bool),
        Some(false)
    );
    assert_eq!(stats.get("dedup_hits").and_then(Json::as_usize), Some(0));
    assert_eq!(
        stats.get("quota_rejections").and_then(Json::as_usize),
        Some(0)
    );

    e.enable_trace();
    let h = e.submit(GenRequest::text("audited request").max_new_tokens(4)).unwrap();
    e.step().unwrap();
    let stats = e.stats_json();
    assert_eq!(
        stats.get("kv_refcount_ok").and_then(Json::as_bool),
        Some(true),
        "a healthy mid-flight engine audits clean"
    );
    assert_eq!(stats.get("trace_enabled").and_then(Json::as_bool), Some(true));
    e.run_to_completion().unwrap();
    let (_, fin) = h.drain();
    let reason = fin.expect("request finishes").0;
    assert!(matches!(
        reason,
        FinishReason::Eos | FinishReason::MaxTokens | FinishReason::Stop
    ));
    assert!(!e.take_trace().is_empty(), "real trace events were recorded");
}
