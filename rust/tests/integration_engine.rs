//! Integration: the serving engine end-to-end on real artifacts —
//! continuous batching, determinism, preemption, async/sync parity,
//! cancellation, and the TCP server round trip — all through the
//! unified [`InferenceEngine`] surface.

use fdpp::api::{FinishReason, GenRequest, InferenceEngine, SubmissionHandle, Usage};
use fdpp::config::EngineConfig;
use fdpp::engine::Engine;
use fdpp::runtime::Runtime;
use fdpp::sampling::SamplingParams;

fn engine_with(cfg: EngineConfig) -> Option<Engine> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(Engine::new(rt, cfg).unwrap()),
        Err(e) => {
            eprintln!("skipping engine integration test (no artifacts): {e}");
            None
        }
    }
}

fn finish_of(h: &SubmissionHandle) -> (Vec<u32>, Option<(FinishReason, Usage)>) {
    h.drain()
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn greedy_generation_is_deterministic() {
    let Some(mut e1) = engine_with(EngineConfig::default()) else {
        return;
    };
    let a = e1
        .generate_text("determinism", 12, SamplingParams::default())
        .unwrap();
    let Some(mut e2) = engine_with(EngineConfig::default()) else {
        return;
    };
    let b = e2
        .generate_text("determinism", 12, SamplingParams::default())
        .unwrap();
    assert_eq!(a, b);
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn continuous_batching_serves_concurrent_requests() {
    let Some(mut engine) = engine_with(EngineConfig::default()) else {
        return;
    };
    let mut handles = vec![];
    for p in ["alpha", "beta prompt", "gamma gamma gamma"] {
        handles.push(engine.submit(GenRequest::text(p).max_new_tokens(10)).unwrap());
    }
    engine.run_to_completion().unwrap();
    for h in &handles {
        let (toks, fin) = finish_of(h);
        assert_eq!(toks.len(), 10);
        let (reason, usage) = fin.unwrap();
        assert_eq!(reason, FinishReason::MaxTokens);
        assert_eq!(usage.generated_tokens, 10);
    }
    // Batched decode actually happened (3 lanes -> bucket 4).
    assert!(engine.metrics.kv_rebuilds >= 1);
    assert_eq!(engine.metrics.requests_finished, 3);
    assert!(engine.metrics.decode_steps < 30, "lanes must share steps");
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn batched_output_matches_solo_output() {
    // A request decoded inside a batch must produce the same tokens as
    // the same request decoded alone (lane isolation, greedy sampling).
    let Some(mut solo) = engine_with(EngineConfig::default()) else {
        return;
    };
    let want = solo
        .generate_text("isolation check", 8, SamplingParams::default())
        .unwrap();

    let Some(mut batched) = engine_with(EngineConfig::default()) else {
        return;
    };
    let h_main = batched
        .submit(GenRequest::text("isolation check").max_new_tokens(8))
        .unwrap();
    let _h_other = batched
        .submit(GenRequest::text("other request padding the batch").max_new_tokens(8))
        .unwrap();
    batched.run_to_completion().unwrap();
    let (toks, _) = finish_of(&h_main);
    let got = batched.tokenizer.decode(&toks);
    assert_eq!(got, want);
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn sync_engine_produces_same_tokens_as_async() {
    let Some(mut a) = engine_with(EngineConfig {
        decode_buckets: vec![1, 8],
        async_softmax: true,
        ..EngineConfig::default()
    }) else {
        return;
    };
    let Some(mut s) = engine_with(EngineConfig {
        decode_buckets: vec![1, 8],
        async_softmax: false,
        ..EngineConfig::default()
    }) else {
        return;
    };
    let pa = a
        .generate_text("parity", 10, SamplingParams::default())
        .unwrap();
    let ps = s
        .generate_text("parity", 10, SamplingParams::default())
        .unwrap();
    assert_eq!(pa, ps, "C1 must not change greedy outputs");
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn preemption_under_kv_pressure() {
    // Tiny KV pool: 3 concurrent sequences cannot all fit; the youngest
    // must be preempted, the others must finish.
    let Some(mut engine) = engine_with(EngineConfig {
        kv_block_tokens: 16,
        kv_total_blocks: 8, // 128 tokens total
        max_new_tokens: 64,
        ..EngineConfig::default()
    }) else {
        return;
    };
    let mut handles = vec![];
    for p in [
        "first request with a long prompt padding",
        "second request also has a long prompt!!",
        "third",
    ] {
        handles.push(engine.submit(GenRequest::text(p).max_new_tokens(60)).unwrap());
    }
    engine.run_to_completion().unwrap();
    let reasons: Vec<_> = handles.iter().map(|h| finish_of(h).1.unwrap().0).collect();
    assert!(
        reasons.iter().any(|r| *r == FinishReason::Preempted),
        "expected at least one preemption, got {reasons:?}"
    );
    assert!(
        reasons
            .iter()
            .filter(|r| **r != FinishReason::Preempted)
            .count()
            >= 1,
        "someone must finish normally: {reasons:?}"
    );
    // All KV blocks returned.
    assert_eq!(engine.metrics.requests_finished, 3);
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn cancel_mid_decode_on_real_engine() {
    let Some(mut engine) = engine_with(EngineConfig::default()) else {
        return;
    };
    let h = engine
        .submit(GenRequest::text("cancel this generation").max_new_tokens(32))
        .unwrap();
    // Step until a couple of tokens streamed, then cancel mid-decode.
    let mut seen = 0;
    while seen < 2 && !engine.is_idle() {
        engine.step().unwrap();
        seen += h.drain().0.len();
    }
    if engine.is_idle() {
        return; // tiny model finished before we could cancel
    }
    assert!(engine.cancel(h.id).unwrap());
    assert!(engine.is_idle());
    let (_, fin) = finish_of(&h);
    assert_eq!(fin.unwrap().0, FinishReason::Cancelled);
    assert_eq!(engine.metrics.cancellations, 1);
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn oversized_prompt_rejected() {
    let Some(mut engine) = engine_with(EngineConfig::default()) else {
        return;
    };
    let long = "x".repeat(100); // > largest prefill bucket (64)
    assert!(engine.submit(GenRequest::text(long).max_new_tokens(4)).is_err());
    // token-less submission rejected too (text prompts always carry BOS)
    assert!(engine.submit(GenRequest::tokens(vec![]).max_new_tokens(4)).is_err());
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn recompute_rate_accounted_and_small() {
    let Some(mut engine) = engine_with(EngineConfig::default()) else {
        return;
    };
    engine
        .generate_text("rate accounting", 16, SamplingParams::default())
        .unwrap();
    let r = engine.metrics.recompute_rate();
    assert!(r < 0.5, "recompute rate {r} suspiciously high");
    assert!(engine.metrics.decode_rows > 0);
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn server_round_trip() {
    if Runtime::load("artifacts").is_err() {
        return;
    }
    let addr = "127.0.0.1:17341";
    let cfg = EngineConfig::default();
    std::thread::spawn(move || {
        let _ = fdpp::server::serve(addr, "artifacts", cfg);
    });
    // Wait for the listener (engine warmup takes a while).
    let mut client = None;
    for _ in 0..600 {
        std::thread::sleep(std::time::Duration::from_millis(100));
        if let Ok(c) = fdpp::server::Client::connect(addr) {
            client = Some(c);
            break;
        }
    }
    let mut client = client.expect("server did not come up");
    let out = client.generate("hello server", 6).unwrap();
    assert!(!out.is_empty());
}
