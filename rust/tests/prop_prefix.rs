//! Property tests for the prefix-sharing subsystem (in-tree randomized
//! harness, same style as prop_invariants.rs):
//!
//! - KV-cache refcount invariants under random alloc / attach / grow /
//!   free / retain interleavings, checked against a shadow refcount
//!   model: no double free, a block returns exactly when its last
//!   reference drops, conservation always holds.
//! - Copy-on-write: a donor's data is never mutated by writes through a
//!   sharing sequence.
//! - Radix tree insert/match/evict round-trips against a functional
//!   shadow model (full-block prefix -> first-registered block).
//! - End-to-end: the shared-prefix workload through the sim engine cuts
//!   prefill tokens >= 50% with byte-identical outputs (ISSUE 1
//!   acceptance).

use std::collections::HashMap;

use fdpp::api::{GenRequest, InferenceEngine};
use fdpp::config::EngineConfig;
use fdpp::kvcache::{KvCache, KvGeometry};
use fdpp::prefixcache::PrefixCache;
use fdpp::simengine::{SimEngine, SimSpec};
use fdpp::util::rng::Rng;
use fdpp::workload::{shared_prefix_trace, SharedPrefixSpec};

const CASES: usize = 60;
const BT: usize = 4;

fn geo() -> KvGeometry {
    KvGeometry {
        n_layers: 1,
        n_heads: 2,
        head_dim: 2,
        block_tokens: BT,
        max_seq: 64,
    }
}

/// Deterministic per-(seq, pos) token column.
fn col(g: &KvGeometry, seq: u64, pos: usize) -> Vec<f32> {
    (0..g.token_elems())
        .map(|e| (seq as f32) * 1000.0 + (pos as f32) * 10.0 + e as f32)
        .collect()
}

/// Refcount invariants under random interleavings of: private alloc,
/// shared attach (block-aligned prefix of a live donor), grow (with
/// COW), free, and tree-style retain/release.
#[test]
fn prop_refcount_invariants() {
    let mut rng = Rng::seed_from_u64(0x9EFC0);
    for case in 0..CASES {
        let g = geo();
        let total = rng.gen_range(8, 24);
        let mut kv = KvCache::new(g, total);
        // Shadow model: expected refcount per block.
        let mut shadow: HashMap<usize, u32> = HashMap::new();
        // live seqs: id -> (blocks at last sync, len)
        let mut live: Vec<u64> = vec![];
        // blocks retained "by the tree" (one extra ref each).
        let mut retained: Vec<usize> = vec![];
        let mut next_id = (case as u64) * 10_000;

        let sync_seq = |kv: &KvCache, shadow: &mut HashMap<usize, u32>, live: &[u64]| {
            // Recompute shadow from ownership sets: every live seq's
            // block table contributes 1 per block, retained adds 1.
            shadow.clear();
            for &id in live {
                for b in kv.seq_blocks(id).unwrap() {
                    *shadow.entry(b).or_insert(0) += 1;
                }
            }
        };

        for _ in 0..80 {
            match rng.gen_range(0, 4) {
                0 => {
                    // Private alloc.
                    let id = next_id;
                    next_id += 1;
                    let toks = rng.gen_range(1, g.max_seq / 2);
                    if kv.alloc_seq(id, toks).is_ok() {
                        live.push(id);
                        for pos in 0..toks {
                            let c = col(&g, id, pos);
                            kv.write_token(id, pos, &c, &c).unwrap();
                        }
                    }
                }
                1 => {
                    // Shared attach: block-aligned prefix of a live donor.
                    if let Some(&donor) = live.get(rng.gen_range(0, live.len().max(1) - 1)) {
                        let donor_blocks = kv.seq_blocks(donor).unwrap();
                        if !donor_blocks.is_empty() {
                            let share_blocks = rng.gen_range(1, donor_blocks.len());
                            let shared_tokens = share_blocks * BT;
                            let extra = rng.gen_range(0, 8);
                            let id = next_id;
                            next_id += 1;
                            if kv
                                .alloc_seq_with_prefix(
                                    id,
                                    shared_tokens + extra,
                                    &donor_blocks[..share_blocks],
                                    shared_tokens,
                                )
                                .is_ok()
                            {
                                live.push(id);
                            }
                        }
                    }
                }
                2 => {
                    // Grow one (may COW a shared tail or allocate).
                    if !live.is_empty() {
                        let id = live[rng.gen_range(0, live.len() - 1)];
                        let _ = kv.grow_one(id);
                    }
                }
                _ => {
                    // Free.
                    if !live.is_empty() {
                        let idx = rng.gen_range(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        kv.free_seq(id).unwrap();
                    }
                }
            }
            // Occasionally retain/release a live block tree-style.
            if rng.gen_range(0, 9) == 0 {
                if let Some(&id) = live.first() {
                    let bs = kv.seq_blocks(id).unwrap();
                    if let Some(&b) = bs.first() {
                        kv.incref_blocks(&[b]);
                        retained.push(b);
                    }
                }
            }
            if rng.gen_range(0, 9) == 0 && !retained.is_empty() {
                let b = retained.swap_remove(rng.gen_range(0, retained.len() - 1));
                kv.decref_blocks(&[b]);
            }

            // Invariant: conservation.
            assert_eq!(
                kv.used_blocks() + kv.free_blocks(),
                total,
                "block conservation violated"
            );
            // Invariant: actual refcounts == ownership count (+ retains).
            sync_seq(&kv, &mut shadow, &live);
            for &b in &retained {
                *shadow.entry(b).or_insert(0) += 1;
            }
            for (&b, &rc) in &shadow {
                assert_eq!(
                    kv.block_refcount(b),
                    rc,
                    "block {b}: refcount drifted from ownership model"
                );
            }
            // Invariant: used == number of blocks with references.
            assert_eq!(
                kv.used_blocks(),
                shadow.len(),
                "a block is live without an owner (leak) or freed while owned"
            );
        }
        // Drain: everything must return exactly once.
        for id in live.drain(..) {
            kv.free_seq(id).unwrap();
        }
        for b in retained.drain(..) {
            kv.decref_blocks(&[b]);
        }
        assert_eq!(kv.free_blocks(), total, "blocks must all return");
    }
}

/// COW: writes through a sharer never change the donor's stored data.
#[test]
fn prop_cow_never_mutates_shared_blocks() {
    let mut rng = Rng::seed_from_u64(0xC07);
    for case in 0..CASES {
        let g = geo();
        let mut kv = KvCache::new(g, 32);
        let donor = case as u64 * 2 + 1;
        let sharer = donor + 1;
        let donor_tokens = rng.gen_range(BT, 24);
        kv.alloc_seq(donor, donor_tokens).unwrap();
        for pos in 0..donor_tokens {
            let c = col(&g, donor, pos);
            kv.write_token(donor, pos, &c, &c).unwrap();
        }
        let donor_blocks = kv.seq_blocks(donor).unwrap();
        // Attach a (possibly partial-tail) prefix.
        let shared_tokens = rng.gen_range(1, donor_tokens);
        let nblocks = shared_tokens.div_ceil(BT);
        kv.alloc_seq_with_prefix(
            sharer,
            shared_tokens + rng.gen_range(1, 8),
            &donor_blocks[..nblocks],
            shared_tokens,
        )
        .unwrap();
        // Hammer writes through the sharer across the shared range and
        // beyond (append-style).
        for _ in 0..12 {
            let pos = rng.gen_range(0, shared_tokens + 3);
            let junk = vec![-9.9f32; g.token_elems()];
            let _ = kv.write_token(sharer, pos, &junk, &junk);
        }
        // Donor data intact, bit for bit.
        let mut kc = vec![0.0f32; g.token_elems()];
        let mut vc = vec![0.0f32; g.token_elems()];
        for pos in 0..donor_tokens {
            kv.read_token(donor, pos, &mut kc, &mut vc).unwrap();
            assert_eq!(kc, col(&g, donor, pos), "donor K mutated at {pos}");
        }
        kv.free_seq(donor).unwrap();
        kv.free_seq(sharer).unwrap();
        assert_eq!(kv.free_blocks(), 32);
    }
}

/// Radix tree vs a functional shadow model: each full-block token
/// prefix maps to the block registered first; match must agree, and
/// eviction only removes (never corrupts) mappings.
#[test]
fn prop_radix_insert_match_evict_roundtrip() {
    let mut rng = Rng::seed_from_u64(0x2AD1);
    for case in 0..CASES {
        let g = geo();
        let total = 64;
        let mut kv = KvCache::new(g, total);
        let mut pc = PrefixCache::new(BT);
        // Shadow: full-block prefix -> block id serving its last block.
        let mut shadow: HashMap<Vec<u32>, usize> = HashMap::new();
        let mut seqs: Vec<u64> = vec![];
        let mut corpus: Vec<Vec<u32>> = vec![];

        for i in 0..10 {
            // Build token ids with deliberate shared prefixes: extend a
            // random existing sequence or start fresh from a tiny
            // alphabet (collisions likely).
            let mut toks: Vec<u32> = if !corpus.is_empty() && rng.gen_range(0, 2) > 0 {
                let base = &corpus[rng.gen_range(0, corpus.len() - 1)];
                let keep = rng.gen_range(0, base.len());
                base[..keep].to_vec()
            } else {
                Vec::new()
            };
            let target = rng.gen_range(BT, 20).max(toks.len());
            while toks.len() < target {
                toks.push(rng.gen_range(0, 2) as u32);
            }
            corpus.push(toks.clone());

            let id = (case * 100 + i) as u64;
            if kv.alloc_seq(id, toks.len()).is_err() {
                continue;
            }
            for pos in 0..toks.len() {
                let c = col(&g, id, pos);
                kv.write_token(id, pos, &c, &c).unwrap();
            }
            seqs.push(id);
            let blocks = kv.seq_blocks(id).unwrap();
            pc.insert(&toks, &blocks, &mut kv);
            // Mirror block-quantized insertion in the shadow model: walk
            // full blocks; an already-stored prefix is deduped; a stored
            // *sibling* sharing the next token but diverging inside the
            // next block stops the insert (sub-block splits are not
            // representable); otherwise the whole remaining tail stores.
            let n_full = toks.len() / BT;
            let mut j = 0;
            while j < n_full {
                let key_j = &toks[..(j + 1) * BT];
                if shadow.contains_key(key_j) {
                    j += 1;
                    continue;
                }
                let conflict = shadow.keys().any(|k| {
                    k.len() == (j + 1) * BT
                        && k[..j * BT] == toks[..j * BT]
                        && k[j * BT] == toks[j * BT]
                });
                if conflict {
                    break;
                }
                for jj in j..n_full {
                    shadow.insert(toks[..(jj + 1) * BT].to_vec(), blocks[jj]);
                }
                break;
            }

            // Match every corpus entry against the shadow.
            for q in &corpus {
                let m = pc.match_prefix(q);
                assert_eq!(m.tokens % BT, 0, "match must be block-quantized");
                assert_eq!(m.blocks.len(), m.tokens / BT);
                // Matched length == longest contiguous shadow coverage.
                let mut expect = 0;
                while expect < q.len() / BT
                    && shadow.contains_key(&q[..(expect + 1) * BT].to_vec())
                {
                    expect += 1;
                }
                assert_eq!(
                    m.tokens,
                    expect * BT,
                    "matched length disagrees with shadow for {q:?}"
                );
                for (j, &b) in m.blocks.iter().enumerate() {
                    assert_eq!(
                        b, shadow[&q[..(j + 1) * BT].to_vec()],
                        "matched block {j} disagrees with first-registered"
                    );
                }
            }
            assert_eq!(
                kv.used_blocks() + kv.free_blocks(),
                total,
                "conservation under insert"
            );
        }

        // Release sequences, then evict everything.
        for id in seqs.drain(..) {
            kv.free_seq(id).unwrap();
        }
        let evictable = pc.cached_blocks();
        let freed = pc.evict(usize::MAX, &mut kv);
        assert_eq!(freed, evictable, "all tree-only blocks must evict");
        assert_eq!(pc.cached_blocks(), 0);
        assert_eq!(kv.free_blocks(), total, "eviction must return every block");
        for q in &corpus {
            assert_eq!(pc.match_prefix(q).tokens, 0, "evicted tree still matches");
        }
    }
}

/// ISSUE 1 acceptance: shared-prefix workload, 8 tenants, Zipf(1.0) —
/// >= 50% prefill-token reduction with byte-identical outputs.
#[test]
fn shared_prefix_workload_halves_prefill_with_identical_outputs() {
    let spec = SharedPrefixSpec {
        n_tenants: 8,
        zipf_s: 1.0,
        seed: 7,
        ..SharedPrefixSpec::default()
    };
    let trace = shared_prefix_trace(&spec);

    // Drive the whole trace through the unified `InferenceEngine`
    // surface (same generic loop as `benches/prefix_reuse.rs`).
    fn drive<E: InferenceEngine>(
        engine: &mut E,
        trace: &[fdpp::workload::TraceRequest],
    ) -> (Vec<Vec<u32>>, u64, f64) {
        let mut handles = vec![];
        for r in trace {
            let req = GenRequest::text(r.prompt.as_str())
                .tenant(r.tenant.as_str())
                .max_new_tokens(r.max_new_tokens);
            handles.push(engine.submit(req).unwrap());
        }
        engine.run_to_completion().unwrap();
        let outs: Vec<Vec<u32>> = handles.iter().map(|h| h.drain().0).collect();
        let m = engine.metrics();
        (outs, m.prefill_tokens_computed, m.prefix_hit_rate())
    }

    let run = |prefix_cache: bool| {
        let cfg = EngineConfig {
            kv_block_tokens: 16,
            kv_total_blocks: 512,
            max_new_tokens: 16,
            prefix_cache,
            ..EngineConfig::default()
        };
        let mut engine = SimEngine::new(cfg, SimSpec::default()).unwrap();
        drive(&mut engine, &trace)
    };

    let (cold_outs, cold_prefill, _) = run(false);
    let (warm_outs, warm_prefill, hit_rate) = run(true);

    assert_eq!(
        cold_outs, warm_outs,
        "prefix reuse must be a pure optimization (byte-identical outputs)"
    );
    let reduction = 1.0 - warm_prefill as f64 / cold_prefill as f64;
    assert!(
        reduction >= 0.5,
        "prefill reduction {reduction:.3} (cold {cold_prefill}, warm {warm_prefill}, \
         hit rate {hit_rate:.2}) below 50% target"
    );
}
