//! Conformance suite for the §5 heuristic dataflow: the inflection
//! table (M1/M2 per [N, K]) must (a) dispatch the argmin
//! implementation at every profiled M, (b) be *stable* under
//! measurement-noise perturbation of the profile, and (c) stay
//! argmin-optimal after noisy profiling. The profiled grid is the four
//! linear shapes of Llama2-7B (Figure 9) over the standard M sweep,
//! against an analytic cost model with crossovers placed between grid
//! points and margins well above the injected noise.

use fdpp::config::paper_model;
use fdpp::dataflow::{default_m_sweep, find_inflections, ImplKind, LookupTable, OpInflection};
use fdpp::util::rng::Rng;

/// Analytic per-op cost model (seconds, arbitrary scale): normalized
/// cost per [N*K] is `c0 + c1 * M`. Coefficients place the A->B
/// crossover at M ~ 11 (between grid points 8 and 16) and the B->C
/// crossover at M ~ 180 (between 128 and 256), with a minimum relative
/// margin of ~19% at any profiled M — far above the 4% noise injected
/// below, so wins can never flip.
fn true_time(kind: ImplKind, m: usize, n: usize, k: usize) -> f64 {
    let scale = (n as f64) * (k as f64) * 1e-12;
    let m = m as f64;
    let normalized = match kind {
        ImplKind::A => 2.0 * m,
        ImplKind::B => 14.3 + 0.7 * m,
        ImplKind::C => 120.0 + 0.1 * m,
    };
    scale * normalized
}

/// Expected inflections for the model above on the default sweep.
const EXPECTED_M1: usize = 16;
const EXPECTED_M2: usize = 256;

fn clean_table() -> LookupTable {
    let model = paper_model("llama2-7b").unwrap();
    let ms = default_m_sweep();
    let mut entries = Vec::new();
    for (op, n, k) in model.linear_shapes() {
        let mut prof =
            |kind: ImplKind, m: usize| -> fdpp::Result<f64> { Ok(true_time(kind, m, n, k)) };
        entries.push(find_inflections(op, n, k, &ms, &mut prof).unwrap());
    }
    LookupTable {
        model: model.name,
        hardware: "analytic".into(),
        entries,
    }
}

fn assert_argmin_dispatch(e: &OpInflection, ms: &[usize]) {
    for &m in ms {
        let chosen = e.dispatch(m);
        let t_chosen = true_time(chosen, m, e.n, e.k);
        for kind in [ImplKind::A, ImplKind::B, ImplKind::C] {
            assert!(
                t_chosen <= true_time(kind, m, e.n, e.k) + 1e-18,
                "{} at M={m}: dispatched {} but {} is faster",
                e.op,
                chosen.as_str(),
                kind.as_str()
            );
        }
    }
}

#[test]
fn clean_profile_finds_the_expected_inflections() {
    let table = clean_table();
    assert_eq!(table.entries.len(), 4, "all four [N,K] shapes profiled");
    for e in &table.entries {
        assert_eq!((e.m1, e.m2), (EXPECTED_M1, EXPECTED_M2), "{}", e.op);
    }
}

#[test]
fn dispatch_is_argmin_on_the_profiled_grid() {
    let table = clean_table();
    let ms = default_m_sweep();
    for e in &table.entries {
        assert_argmin_dispatch(e, &ms);
    }
    // Spot-check the table's lookup surface too (op-keyed dispatch).
    assert_eq!(table.dispatch("qkv_proj", 1).unwrap(), ImplKind::A);
    assert_eq!(table.dispatch("qkv_proj", EXPECTED_M1).unwrap(), ImplKind::B);
    assert_eq!(table.dispatch("ffn2", EXPECTED_M2).unwrap(), ImplKind::C);
    assert!(table.dispatch("unknown_op", 8).is_err());
}

#[test]
fn inflections_are_stable_under_measurement_noise() {
    // 50 seeded noisy re-profiles: multiplicative noise up to +/-4% on
    // every measurement. The decision flow's monotone-suffix rule plus
    // the model's margins must yield the *identical* table every time.
    let model = paper_model("llama2-7b").unwrap();
    let ms = default_m_sweep();
    for seed in 0..50u64 {
        let mut rng = Rng::seed_from_u64(0xDA7AF10 ^ seed);
        for (op, n, k) in model.linear_shapes() {
            let mut prof = |kind: ImplKind, m: usize| -> fdpp::Result<f64> {
                let noise = 1.0 + 0.04 * (2.0 * rng.next_f64() - 1.0);
                Ok(true_time(kind, m, n, k) * noise)
            };
            let e = find_inflections(op, n, k, &ms, &mut prof).unwrap();
            assert_eq!(
                (e.m1, e.m2),
                (EXPECTED_M1, EXPECTED_M2),
                "{op} seed {seed}: noise perturbed the inflection table"
            );
            assert_argmin_dispatch(&e, &ms);
        }
    }
}

#[test]
fn dispatch_is_monotone_a_b_c_for_any_inflections() {
    // Structural property of the lookup: as M grows, the chosen
    // implementation only ever moves A -> B -> C, never backwards —
    // whatever (m1, m2) the profile produced.
    let mut rng = Rng::seed_from_u64(0x5EED_D15B);
    for _ in 0..200 {
        let m1 = rng.gen_range(1, 300);
        let m2 = m1.max(rng.gen_range(1, 600));
        let e = OpInflection {
            op: "x".into(),
            n: 64,
            k: 64,
            m1,
            m2,
        };
        let mut last = 0u8;
        for m in 0..700 {
            let rank = match e.dispatch(m) {
                ImplKind::A => 0,
                ImplKind::B => 1,
                ImplKind::C => 2,
            };
            assert!(rank >= last, "dispatch regressed at M={m} (m1={m1}, m2={m2})");
            last = rank;
        }
        assert_eq!(e.dispatch(m2.max(m1)), ImplKind::C);
    }
}
