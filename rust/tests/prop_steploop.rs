//! Property test for the step loop's zero-allocation steady state:
//! once a randomized decode world has warmed up (streams, flight ring,
//! scratch arenas, and KV block tables all at their high-water
//! capacity), an engine step that only generates tokens — no
//! admission, finish, preemption, pause/resume, or cancel — performs
//! **zero** heap allocations, at every chunk size.
//!
//! The test binary installs a counting global allocator and samples it
//! around each `engine.step()` call. Steps are classified *after the
//! fact* from the engine's own metrics deltas, so the test needs no
//! knowledge of the scheduler's plans: a step is steady-state decode
//! iff `tokens_generated` rose while every lifecycle counter
//! (admitted, finished, preemptions, pauses, resumes, cancellations)
//! and `prefill_steps` stayed put. Grouped decode is exempt from the
//! zero-alloc claim (group formation allocates by design) and is kept
//! off here.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fdpp::api::{GenRequest, InferenceEngine};
use fdpp::config::EngineConfig;
use fdpp::simengine::{SimEngine, SimSpec};
use fdpp::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Lifecycle counters whose movement disqualifies a step from the
/// steady-state claim.
#[derive(Clone, Copy, PartialEq, Eq)]
struct Lifecycle {
    admitted: u64,
    finished: u64,
    preemptions: u64,
    pauses: u64,
    resumes: u64,
    cancellations: u64,
    prefill_steps: u64,
}

fn lifecycle(e: &SimEngine) -> Lifecycle {
    let m = &e.metrics;
    Lifecycle {
        admitted: m.requests_admitted,
        finished: m.requests_finished,
        preemptions: m.preemptions,
        pauses: m.backpressure_pauses,
        resumes: m.backpressure_resumes,
        cancellations: m.cancellations,
        prefill_steps: m.prefill_steps,
    }
}

/// Tokens generated before a step counts as warmed up: past the flight
/// ring's fill (64 entries — recycling kicks in after that), every
/// stream's `VecDeque` growth, and every scratch arena's first
/// high-water fill.
const WARMUP_TOKENS: u64 = 96;

#[test]
fn steady_state_decode_allocates_nothing() {
    let mut rng = Rng::seed_from_u64(0x57EF_100F);
    let mut steady_total = 0u64;
    for world in 0..24u64 {
        let chunk = [1usize, 2, 4, 8][rng.gen_range(0, 4)];
        let batch = 1 + rng.gen_range(0, 8);
        let cfg = EngineConfig {
            kv_block_tokens: if rng.next_u64() % 2 == 0 { 4 } else { 8 },
            kv_total_blocks: 512,
            max_new_tokens: 256,
            max_running: batch,
            decode_buckets: vec![1, 2, 4, 8],
            prefix_cache: false,
            stream_capacity: 64,
            flight_recorder_capacity: 64,
            decode_chunk: chunk,
            seed: world,
            ..EngineConfig::default()
        };
        let mut engine = SimEngine::new(cfg, SimSpec::default()).expect("engine builds");
        let mut handles = Vec::with_capacity(batch);
        for i in 0..batch {
            let words = 1 + rng.gen_range(0, 8);
            let mut prompt = format!("world {world} req {i}");
            for w in 0..words {
                prompt.push_str(&format!(" tok{w}"));
            }
            let req = GenRequest::text(&prompt).max_new_tokens(160 + rng.gen_range(0, 64));
            handles.push(engine.submit(req).expect("submit accepted"));
        }

        let mut steps = 0u64;
        while !engine.is_idle() {
            assert!(steps < 100_000, "world {world} did not drain");
            let before = lifecycle(&engine);
            let tokens_before = engine.metrics.tokens_generated;
            let a0 = ALLOCS.load(Ordering::Relaxed);
            engine.step().expect("step succeeds");
            let a1 = ALLOCS.load(Ordering::Relaxed);
            let after = lifecycle(&engine);
            let emitted = engine.metrics.tokens_generated > tokens_before;
            if emitted && before == after && tokens_before >= WARMUP_TOKENS {
                assert_eq!(
                    a1 - a0,
                    0,
                    "world {world} (chunk {chunk}, batch {batch}) step {steps}: \
                     steady-state decode performed {} heap allocations",
                    a1 - a0
                );
                steady_total += 1;
            }
            // Drain outside the measured window so client-side reads
            // never pollute the step's allocation count.
            for h in &handles {
                while h.events.try_recv().is_ok() {}
            }
            steps += 1;
        }
    }
    assert!(
        steady_total > 500,
        "only {steady_total} steady-state steps classified — the worlds \
         are not exercising the claim"
    );
}
