//! Property tests for flow control and priority-aware preemption
//! (in-tree randomized harness, same style as prop_invariants.rs):
//!
//! - `preemption_victim` ordering: over random candidate sets, the
//!   victim always has the minimum priority; within that level the most
//!   reusable blocks; within that, the largest id (youngest). Corollary
//!   (ISSUE 3 acceptance): no request is ever preempted while a
//!   strictly lower-priority victim exists.
//! - End-to-end through the sim engine: under forced KV exhaustion with
//!   two mixed-priority requests, the lower-priority one is always the
//!   preemption victim, whatever the submission order; equal priorities
//!   fall back to preempting the youngest.
//! - Bounded streams: under random drain schedules, a request's
//!   undelivered-token buffer never exceeds the configured capacity,
//!   and `PauseDecode` is lossless — every generated token is
//!   eventually delivered, in order, exactly once.
//! - `DropSlow`: an undrained consumer is finished with `overrun`,
//!   keeps exactly its buffered tokens, and every KV block returns.

use fdpp::api::{FinishReason, GenEvent, GenRequest, InferenceEngine};
use fdpp::config::{BackpressurePolicy, EngineConfig};
use fdpp::scheduler::{preemption_victim, PreemptCandidate};
use fdpp::simengine::{SimEngine, SimSpec};
use fdpp::util::rng::Rng;

const CASES: usize = 120;

#[test]
fn prop_preemption_victim_orders_by_priority_reusable_recency() {
    let mut rng = Rng::seed_from_u64(0xF10C7);
    for _ in 0..CASES {
        let n = rng.gen_range(1, 8);
        let mut cands = Vec::with_capacity(n);
        for i in 0..n {
            cands.push(PreemptCandidate {
                id: (i as u64 + 1) * 3, // distinct, increasing = age order
                priority: rng.gen_range(0, 6) as i32 - 3,
                paused: false, // all running: the classic ordering
                reusable_blocks: rng.gen_range(0, 4),
            });
        }
        let victim = preemption_victim(&cands).expect("non-empty candidate set");
        let v = cands.iter().find(|c| c.id == victim).unwrap();
        let min_priority = cands.iter().map(|c| c.priority).min().unwrap();
        // The acceptance property: never preempt while a strictly
        // lower-priority victim exists.
        assert_eq!(
            v.priority, min_priority,
            "victim {victim} has priority {} but {min_priority} exists: {cands:?}",
            v.priority
        );
        let level: Vec<_> = cands.iter().filter(|c| c.priority == min_priority).collect();
        let max_reusable = level.iter().map(|c| c.reusable_blocks).max().unwrap();
        assert_eq!(
            v.reusable_blocks, max_reusable,
            "within the level, most reusable blocks loses first: {cands:?}"
        );
        let youngest = level
            .iter()
            .filter(|c| c.reusable_blocks == max_reusable)
            .map(|c| c.id)
            .max()
            .unwrap();
        assert_eq!(victim, youngest, "remaining ties go to the youngest: {cands:?}");
    }
}

#[test]
fn prop_parked_victim_preferred_within_priority_level() {
    // ISSUE 4 satellite: within a priority level, parked
    // (backpressure-paused) victims lose before running ones; priority
    // still dominates, and the reusable/recency order applies among
    // candidates of the same parked-ness.
    let mut rng = Rng::seed_from_u64(0xAA_4D1D3);
    for _ in 0..CASES {
        let n = rng.gen_range(1, 8);
        let mut cands = Vec::with_capacity(n);
        for i in 0..n {
            cands.push(PreemptCandidate {
                id: (i as u64 + 1) * 3,
                priority: rng.gen_range(0, 4) as i32 - 2,
                paused: rng.gen_range(0, 1) == 1,
                reusable_blocks: rng.gen_range(0, 4),
            });
        }
        let victim = preemption_victim(&cands).expect("non-empty candidate set");
        let v = *cands.iter().find(|c| c.id == victim).unwrap();
        let min_priority = cands.iter().map(|c| c.priority).min().unwrap();
        assert_eq!(v.priority, min_priority, "priority dominates: {cands:?}");
        let level: Vec<_> = cands
            .iter()
            .filter(|c| c.priority == min_priority)
            .collect();
        if level.iter().any(|c| c.paused) {
            assert!(
                v.paused,
                "a parked victim existed at the level but a running one \
                 was preempted: {cands:?}"
            );
        }
        let peers: Vec<_> = level.iter().filter(|c| c.paused == v.paused).collect();
        let max_reusable = peers.iter().map(|c| c.reusable_blocks).max().unwrap();
        assert_eq!(v.reusable_blocks, max_reusable, "{cands:?}");
        let youngest = peers
            .iter()
            .filter(|c| c.reusable_blocks == max_reusable)
            .map(|c| c.id)
            .max()
            .unwrap();
        assert_eq!(victim, youngest, "{cands:?}");
    }
}

/// Budget sized so the duel's survivor fits the 6-block pool after the
/// preemption frees the victim's 3 blocks (8 prompt + 12 generated
/// tokens <= 24 slots).
const DUEL_BUDGET: usize = 12;

fn duel_cfg() -> EngineConfig {
    EngineConfig {
        kv_block_tokens: 4,
        kv_total_blocks: 6,
        max_new_tokens: DUEL_BUDGET,
        max_running: 4,
        decode_buckets: vec![1, 2, 4],
        prefix_cache: false,
        ..EngineConfig::default()
    }
}

/// A 7-char prompt (8 tokens with BOS = 3 KV blocks of 4 with the +1
/// slot) whose first generated token is not EOS, so a duel participant
/// can never finish before the first decode step. Deterministic: the
/// hash model is a pure function of the prompt.
fn duel_prompt(tag: u32) -> String {
    for salt in 0..512u32 {
        let p = format!("d{tag}x{salt:04}"); // always exactly 7 chars
        assert_eq!(p.len(), 7);
        let mut e = SimEngine::new(
            EngineConfig {
                kv_total_blocks: 64,
                ..duel_cfg()
            },
            SimSpec::default(),
        )
        .unwrap();
        let h = e.submit(GenRequest::text(&p).max_new_tokens(2)).unwrap();
        e.run_to_completion().unwrap();
        if h.drain().0.len() == 2 {
            return p;
        }
    }
    panic!("no duel prompt survives two tokens");
}

/// Force exactly one preemption between two running sequences and
/// return their finish reasons (first-submitted, second-submitted).
fn run_preemption_duel(pa: i32, pb: i32) -> (FinishReason, FinishReason) {
    // Tiny pool, prefix cache off: both sequences admit (3 blocks
    // each of the 6), then decode growth exhausts the pool and the
    // policy must preempt exactly one of them at the first decode step.
    let mut e = SimEngine::new(duel_cfg(), SimSpec::default()).unwrap();
    let a = e
        .submit(
            GenRequest::text(duel_prompt(0))
                .priority(pa)
                .max_new_tokens(DUEL_BUDGET),
        )
        .unwrap();
    let b = e
        .submit(
            GenRequest::text(duel_prompt(1))
                .priority(pb)
                .max_new_tokens(DUEL_BUDGET),
        )
        .unwrap();
    let mut fin_a = None;
    let mut fin_b = None;
    let mut steps = 0;
    while fin_a.is_none() || fin_b.is_none() {
        if !e.is_idle() {
            e.step().unwrap();
        }
        if fin_a.is_none() {
            fin_a = a.drain().1;
        }
        if fin_b.is_none() {
            fin_b = b.drain().1;
        }
        steps += 1;
        assert!(steps < 10_000, "duel must terminate");
    }
    assert!(e.metrics.preemptions >= 1, "pool of 6 blocks must force preemption");
    (fin_a.unwrap().0, fin_b.unwrap().0)
}

#[test]
fn prop_lower_priority_always_preempted_first() {
    let mut rng = Rng::seed_from_u64(0xBEEFED);
    for _ in 0..40 {
        let hi = rng.gen_range(1, 5) as i32;
        let lo = -(rng.gen_range(0, 4) as i32);
        // Submission order must not matter: try both.
        let (fa, fb) = run_preemption_duel(hi, lo);
        assert_ne!(fa, FinishReason::Preempted, "high priority survived (hi first)");
        assert_eq!(fb, FinishReason::Preempted, "low priority is the victim");
        let (fa, fb) = run_preemption_duel(lo, hi);
        assert_eq!(fa, FinishReason::Preempted, "low priority is the victim");
        assert_ne!(fb, FinishReason::Preempted, "high priority survived (lo first)");
    }
    // Equal priorities: the youngest (second submit) is preempted.
    let (fa, fb) = run_preemption_duel(0, 0);
    assert_ne!(fa, FinishReason::Preempted);
    assert_eq!(fb, FinishReason::Preempted);
}

/// A 7-char prompt (8 tokens with BOS = 3 KV blocks of 4 with the +1
/// slot) whose generation survives at least 4 tokens on a roomy pool.
fn park_prompt(tag: u32) -> String {
    for salt in 0..512u32 {
        let p = format!("k{tag}x{salt:04}");
        assert_eq!(p.len(), 7);
        let mut e = SimEngine::new(
            EngineConfig {
                kv_total_blocks: 64,
                stream_capacity: 64,
                ..duel_cfg()
            },
            SimSpec::default(),
        )
        .unwrap();
        let h = e.submit(GenRequest::text(&p).max_new_tokens(4)).unwrap();
        e.run_to_completion().unwrap();
        if h.drain().0.len() == 4 {
            return p;
        }
    }
    panic!("no prompt survives 4 tokens");
}

#[test]
fn parked_victim_preempted_before_running_at_equal_priority() {
    // End-to-end corollary of the property above: two equal-priority
    // requests on a 6-block pool; one client stalls (its request
    // parks), the other keeps draining. Decode growth exhausts the
    // pool; the *parked* request must be the victim even though it is
    // older (the old recency rule would have preempted the live one).
    let cfg = EngineConfig {
        stream_capacity: 2,
        backpressure: BackpressurePolicy::PauseDecode,
        ..duel_cfg()
    };
    let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
    let stalled = e
        .submit(GenRequest::text(park_prompt(0)).priority(1).max_new_tokens(DUEL_BUDGET))
        .unwrap();
    // Park the stalled client: its 2-slot stream fills, PauseDecode
    // takes its lane.
    for _ in 0..6 {
        e.step().unwrap();
    }
    assert_eq!(e.paused(), 1, "stalled request parked");
    let live = e
        .submit(GenRequest::text(park_prompt(1)).priority(1).max_new_tokens(DUEL_BUDGET))
        .unwrap();
    let mut live_fin = None;
    let mut steps = 0;
    while live_fin.is_none() {
        if !e.is_idle() {
            e.step().unwrap();
        }
        let (_, f) = live.drain();
        if f.is_some() {
            live_fin = f;
        }
        steps += 1;
        assert!(steps < 10_000, "duel must terminate");
    }
    assert!(e.metrics.preemptions >= 1, "6-block pool must preempt");
    assert_ne!(
        live_fin.unwrap().0,
        FinishReason::Preempted,
        "the draining client survives"
    );
    let (_, stalled_fin) = stalled.drain();
    assert_eq!(
        stalled_fin.unwrap().0,
        FinishReason::Preempted,
        "the parked equal-priority request is the victim"
    );
}

#[test]
fn prop_bounded_streams_are_lossless_under_random_drains() {
    let mut rng = Rng::seed_from_u64(0x51_0BED);
    for case in 0..30 {
        let capacity = rng.gen_range(1, 5);
        let budget = rng.gen_range(4, 20);
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 64,
            prefix_cache: true,
            stream_capacity: capacity,
            backpressure: BackpressurePolicy::PauseDecode,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        let prompt = format!("lossless case {case}");
        let h = e
            .submit(GenRequest::text(&prompt).max_new_tokens(budget))
            .unwrap();
        let mut got: Vec<u32> = Vec::new();
        let mut fin = None;
        let mut steps = 0;
        while fin.is_none() {
            if !e.is_idle() {
                e.step().unwrap();
            }
            // The buffer never exceeds the configured capacity, drained
            // or not.
            assert!(
                h.events.buffered() <= capacity,
                "buffer {} exceeds capacity {capacity}",
                h.events.buffered()
            );
            // Random drain schedule: sometimes nothing, sometimes a
            // few events.
            for _ in 0..rng.gen_range(0, 3) {
                match h.events.try_recv() {
                    Ok(GenEvent::Token(t)) => got.push(t),
                    Ok(GenEvent::Finished { reason, usage }) => fin = Some((reason, usage)),
                    Err(_) => break,
                }
            }
            steps += 1;
            assert!(steps < 50_000, "case {case} must terminate");
        }
        let (_, usage) = fin.unwrap();
        // Lossless: every generated token was delivered exactly once,
        // in order (the sim is deterministic-greedy, so compare against
        // an unpressured reference run).
        assert_eq!(got.len(), usage.generated_tokens);
        let mut reference = SimEngine::new(
            EngineConfig {
                stream_capacity: 256,
                ..e.cfg.clone()
            },
            SimSpec::default(),
        )
        .unwrap();
        let r = reference
            .submit(GenRequest::text(&prompt).max_new_tokens(budget))
            .unwrap();
        reference.run_to_completion().unwrap();
        assert_eq!(got, r.drain().0, "case {case}: token stream must match");
    }
}

#[test]
fn prop_drop_slow_overruns_exactly_at_capacity_and_frees_kv() {
    let mut rng = Rng::seed_from_u64(0xD20_B5);
    for case in 0..20 {
        let capacity = rng.gen_range(1, 5);
        let total_blocks = 64;
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: total_blocks,
            max_new_tokens: 64,
            prefix_cache: false,
            stream_capacity: capacity,
            backpressure: BackpressurePolicy::DropSlow,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        let h = e
            .submit(GenRequest::text(format!("drop case {case}")).max_new_tokens(64))
            .unwrap();
        // Never drain; completion must not need the client.
        e.run_to_completion().unwrap();
        let (toks, fin) = h.drain();
        let (reason, usage) = fin.expect("finish event always lands");
        if reason == FinishReason::Overrun {
            assert_eq!(toks.len(), capacity, "exactly the buffered tokens survive");
            assert_eq!(usage.generated_tokens, capacity);
            assert_eq!(e.metrics.backpressure_drops, 1);
        } else {
            // The hash model may hit EOS before the buffer fills — then
            // no overrun, and everything fit in the buffer.
            assert!(toks.len() <= capacity);
        }
        assert_eq!(
            e.kv_free_blocks(),
            total_blocks,
            "case {case}: every KV block returns (cache off)"
        );
        assert!(e.is_idle());
    }
}
