//! Tier-1 simulation scenarios: the fixed seed matrix every PR runs,
//! plus targeted deterministic scenarios for the flow-control
//! satellites (condvar-resume latency, parked-idle timeout).
//!
//! Each seed expands into a full scripted world (mixed tenants and
//! priorities, shared prefixes, slow/stalled/disconnecting readers,
//! cancels, admin bulk-cancels, tiny KV pools and stream buffers) and
//! runs with all four oracles armed after every step — see
//! `fdpp::simtest` and docs/ARCHITECTURE.md § "Testing & determinism".
//! On failure the harness prints the seed and a replay command.

use std::time::Duration;

use fdpp::api::{FinishReason, GenRequest, InferenceEngine};
use fdpp::config::{BackpressurePolicy, EngineConfig};
use fdpp::simengine::{SimEngine, SimSpec, TraceEvent, SIM_STEP};
use fdpp::simtest::{generate_scenario, run_crash_recovery, run_scenario, Reader};

/// The fixed matrix: 24 seeds (>= 20 scenarios) on every PR. Chosen
/// densely from 1 so a failure's replay command is obvious.
const SEED_MATRIX: std::ops::RangeInclusive<u64> = 1..=24;

#[test]
fn seed_matrix_passes_all_oracles_and_covers_the_fault_plane() {
    // One pass over the matrix does double duty: every seed must pass
    // all four oracles, and — because the matrix is only worth its
    // runtime if the generated scenarios exercise the interesting
    // machinery — backpressure pauses, resumes, preemptions, cancels,
    // disconnects, and idle expiries must all appear somewhere in the
    // aggregate.
    let mut failures = Vec::new();
    let mut pauses = 0u64;
    let mut resumes = 0u64;
    let mut preemptions = 0u64;
    let mut cancellations = 0u64;
    let mut disconnects = 0u64;
    let mut expired = 0u64;
    let mut tokens = 0u64;
    for seed in SEED_MATRIX {
        match run_scenario(seed) {
            Ok(r) => {
                pauses += r.pauses;
                resumes += r.resumes;
                preemptions += r.preemptions;
                cancellations += r.cancellations;
                disconnects += r.disconnects;
                expired += r.expired;
                tokens += r.tokens_generated;
            }
            Err(v) => {
                eprintln!("{v}");
                failures.push(seed);
            }
        }
    }
    assert!(failures.is_empty(), "failing seeds: {failures:?}");
    assert!(tokens > 100, "matrix generated {tokens} tokens");
    assert!(pauses > 0, "no scenario exercised backpressure pauses");
    assert!(resumes > 0, "no scenario exercised resumes");
    assert!(preemptions > 0, "no scenario exercised preemption");
    assert!(cancellations > 0, "no scenario exercised cancels");
    assert!(disconnects > 0, "no scenario exercised disconnects");
    assert!(expired > 0, "no scenario exercised the idle timeout");
}

#[test]
fn crash_recovery_rebuilds_from_registry_with_oracles_intact() {
    // Scripted mid-run engine crash over part of the seed matrix: the
    // core is dropped at a seed-derived step, a fresh core is built,
    // and the registry's surviving entries are resubmitted. The KV
    // refcount oracle runs on every step of both engine lives; every
    // retained client must still receive a terminal event, and the
    // rebuilt core must drain to a clean audit. The aggregate must
    // actually exercise recovery (some run resubmits in-flight work) —
    // otherwise the crash step landed before any request ever started.
    let mut failures = Vec::new();
    let mut resubmitted = 0usize;
    let mut finished_before = 0usize;
    let mut finished_after = 0u64;
    for seed in 1..=12u64 {
        match run_crash_recovery(seed) {
            Ok(r) => {
                resubmitted += r.resubmitted;
                finished_before += r.finished_before_crash;
                finished_after += r.finished_after_recovery;
            }
            Err(v) => {
                eprintln!("{v}");
                failures.push(seed);
            }
        }
    }
    assert!(failures.is_empty(), "failing seeds: {failures:?}");
    assert!(
        resubmitted > 0,
        "no run resubmitted in-flight work after the crash"
    );
    assert!(finished_after > 0, "recovered cores finished requests");
    // Requests that finished before the crash stay finished — recovery
    // never re-runs them (the registry had already pruned their gids).
    let _ = finished_before;
}

#[test]
fn scenario_generator_emits_every_reader_kind() {
    let mut eager = 0;
    let mut slow = 0;
    let mut stall = 0;
    let mut disconnect = 0;
    for seed in SEED_MATRIX {
        for c in generate_scenario(seed).clients {
            match c.reader {
                Reader::Eager => eager += 1,
                Reader::EveryK { .. } => slow += 1,
                Reader::StallAfter { .. } => stall += 1,
                Reader::DisconnectAfter { .. } => disconnect += 1,
            }
        }
    }
    assert!(eager > 0 && slow > 0 && stall > 0 && disconnect > 0);
}

// ---------------------------------------------------------------------
// Satellite: deterministic resume latency (condvar wakeup follow-up)
// ---------------------------------------------------------------------

/// A prompt whose unconstrained greedy generation runs at least
/// `min_tokens` (the hash model is deterministic, so this is a stable
/// selection, not a retry loop).
fn probe_prompt(tag: &str, min_tokens: usize) -> String {
    for salt in 0..64u32 {
        let p = format!("{tag} probe {salt}");
        let mut e = SimEngine::new(
            EngineConfig {
                kv_block_tokens: 8,
                kv_total_blocks: 64,
                max_new_tokens: 32,
                stream_capacity: 64,
                ..EngineConfig::default()
            },
            SimSpec::default(),
        )
        .unwrap();
        let h = e.submit(GenRequest::text(&p).max_new_tokens(24)).unwrap();
        e.run_to_completion().unwrap();
        if h.drain().0.len() >= min_tokens {
            return p;
        }
    }
    panic!("no probe prompt generates {min_tokens}+ tokens");
}

/// Drive one slow consumer to a park, drain it below the resume
/// threshold, and return (pause_step, resume_step) observed in the
/// trace, stepping deterministically.
fn park_and_resume_steps() -> (usize, usize) {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 64,
        max_new_tokens: 24,
        stream_capacity: 2,
        backpressure: BackpressurePolicy::PauseDecode,
        ..EngineConfig::default()
    };
    let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
    e.enable_trace();
    let h = e
        .submit(GenRequest::text(probe_prompt("resume", 8)).max_new_tokens(24))
        .unwrap();
    let mut pause_step = None;
    let mut resume_step = None;
    for step in 0..200 {
        if !e.is_idle() {
            e.step().unwrap();
        }
        for ev in e.take_trace() {
            match ev {
                TraceEvent::Paused { .. } if pause_step.is_none() => pause_step = Some(step),
                TraceEvent::Resumed { .. } if resume_step.is_none() => resume_step = Some(step),
                _ => {}
            }
        }
        // The instant it parks, drain fully: the very next step must
        // resume it (capacity 2, buffered 0 <= 1 = capacity/2).
        if pause_step == Some(step) {
            let (t, _) = h.drain();
            assert!(!t.is_empty());
        }
        if resume_step.is_some() {
            break;
        }
    }
    (
        pause_step.expect("slow consumer must park"),
        resume_step.expect("drained consumer must resume"),
    )
}

#[test]
fn resume_latency_is_deterministic_and_immediate() {
    let (pause_a, resume_a) = park_and_resume_steps();
    let (pause_b, resume_b) = park_and_resume_steps();
    assert_eq!((pause_a, resume_a), (pause_b, resume_b), "deterministic");
    assert_eq!(
        resume_a,
        pause_a + 1,
        "a drained stream resumes on the very next step — resume latency \
         is one step (one SIM_STEP of virtual time), not a poll quantum"
    );
}

// ---------------------------------------------------------------------
// Satellite: parked-idle timeout demotes to overrun
// ---------------------------------------------------------------------

#[test]
fn long_parked_request_expires_to_overrun_and_frees_kv() {
    const TIMEOUT_MS: u64 = 10;
    let total_blocks = 64;
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: total_blocks,
        max_new_tokens: 24,
        prefix_cache: false,
        stream_capacity: 2,
        backpressure: BackpressurePolicy::PauseDecode,
        stream_idle_timeout_ms: TIMEOUT_MS,
        ..EngineConfig::default()
    };
    let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
    e.enable_trace();
    let h = e
        .submit(GenRequest::text(probe_prompt("idle", 8)).max_new_tokens(24))
        .unwrap();
    // Never drain: the request parks, sits idle, and must be demoted
    // without any admission pressure. run_to_completion would have
    // wedged forever before the timeout existed.
    let mut steps = 0;
    while !e.is_idle() {
        e.step().unwrap();
        steps += 1;
        assert!(steps < 1000, "idle timeout must unpark the engine");
    }
    let trace = e.take_trace();
    let paused_at = trace
        .iter()
        .position(|ev| matches!(ev, TraceEvent::Paused { .. }))
        .expect("parks first");
    let expired_at = trace
        .iter()
        .position(|ev| matches!(ev, TraceEvent::Expired { .. }))
        .expect("expires later");
    assert!(paused_at < expired_at);
    let (toks, fin) = h.drain();
    let (reason, usage) = fin.expect("terminal event still delivered");
    assert_eq!(reason, FinishReason::Overrun);
    assert_eq!(toks.len(), usage.generated_tokens, "buffered tokens survive");
    assert_eq!(e.metrics.stream_idle_drops, 1);
    assert_eq!(e.kv_free_blocks(), total_blocks, "parked KV reclaimed");
    // The demotion happened at (not before) the deadline: the park ran
    // the full timeout in virtual time.
    let min_steps = (TIMEOUT_MS as u128) / SIM_STEP.as_millis();
    assert!(
        steps as u128 >= min_steps,
        "expired after {steps} steps, timeout is {min_steps}"
    );
}

#[test]
fn idle_timeout_never_fires_for_cooperating_clients() {
    // Same setup, but the client drains every step: no expiry, normal
    // completion, even far past the timeout in virtual time.
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 64,
        max_new_tokens: 24,
        stream_capacity: 2,
        backpressure: BackpressurePolicy::PauseDecode,
        stream_idle_timeout_ms: 3,
        ..EngineConfig::default()
    };
    let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
    let h = e
        .submit(GenRequest::text(probe_prompt("coop", 8)).max_new_tokens(24))
        .unwrap();
    let mut got = Vec::new();
    let mut fin = None;
    let mut steps = 0;
    while fin.is_none() {
        if !e.is_idle() {
            e.step().unwrap();
        }
        let (mut t, f) = h.drain();
        got.append(&mut t);
        if f.is_some() {
            fin = f;
        }
        steps += 1;
        assert!(steps < 1000);
    }
    let (reason, usage) = fin.unwrap();
    assert_ne!(reason, FinishReason::Overrun, "drained client never expires");
    assert_eq!(got.len(), usage.generated_tokens);
    assert_eq!(e.metrics.stream_idle_drops, 0);
}

#[test]
fn clock_advances_one_quantum_per_step() {
    let mut e = SimEngine::new(
        EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 32,
            ..EngineConfig::default()
        },
        SimSpec::default(),
    )
    .unwrap();
    let clock = e.clock();
    assert!(clock.is_manual());
    assert_eq!(clock.now(), Duration::ZERO);
    let _h = e.submit(GenRequest::text("tick").max_new_tokens(2)).unwrap();
    for i in 1..=5u32 {
        e.step().unwrap();
        assert_eq!(clock.now(), SIM_STEP * i, "virtual time is step count");
    }
}
