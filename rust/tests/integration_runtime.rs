//! Integration: the full AOT path — Rust loads the HLO artifacts and the
//! numbers coming back through PJRT must match the oracle-attention
//! variants and be internally consistent across entry points.
//!
//! Requires `make artifacts` (skips gracefully when absent).

use fdpp::runtime::{literal_f32, literal_i32, to_vec_f32, Manifest, Runtime};

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn zero_cache(rt: &Runtime, b: usize) -> (xla::Literal, xla::Literal, [usize; 5]) {
    let m = &rt.manifest.model;
    let shape = [m.n_layers, b, m.n_heads, m.max_seq, m.head_dim];
    let n: usize = shape.iter().product();
    (
        literal_f32(&vec![0.0; n], &shape).unwrap(),
        literal_f32(&vec![0.0; n], &shape).unwrap(),
        shape,
    )
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn decode_async_matches_oracle_attention_entry() {
    let Some(mut rt) = runtime() else { return };
    let (kc, vc, _) = zero_cache(&rt, 1);
    let toks = literal_i32(&[42], &[1]).unwrap();
    let pos = literal_i32(&[0], &[1]).unwrap();
    let a = rt
        .execute("decode_b1", &[&toks, &pos, &kc, &vc])
        .unwrap();
    let b = rt
        .execute("decode_b1_jnpattn", &[&toks, &pos, &kc, &vc])
        .unwrap();
    let la = to_vec_f32(&a[0]).unwrap();
    let lb = to_vec_f32(&b[0]).unwrap();
    let d = max_abs_diff(&la, &lb);
    assert!(d < 2e-3, "async-kernel logits vs oracle logits: {d}");
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn decode_sync_matches_async() {
    let Some(mut rt) = runtime() else { return };
    let (kc, vc, _) = zero_cache(&rt, 1);
    let toks = literal_i32(&[7], &[1]).unwrap();
    let pos = literal_i32(&[0], &[1]).unwrap();
    let a = rt.execute("decode_b1", &[&toks, &pos, &kc, &vc]).unwrap();
    let s = rt
        .execute("decode_b1_sync", &[&toks, &pos, &kc, &vc])
        .unwrap();
    let d = max_abs_diff(&to_vec_f32(&a[0]).unwrap(), &to_vec_f32(&s[0]).unwrap());
    assert!(d < 2e-3, "sync vs async logits: {d}");
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn prefill_then_decode_consistent_with_longer_prefill() {
    let Some(mut rt) = runtime() else { return };
    let m = rt.manifest.model.clone();
    let vocab = m.vocab_size;
    // 9 deterministic tokens.
    let toks9: Vec<i32> = (0..9).map(|i| ((i * 37 + 11) % vocab) as i32).collect();

    // Full prefill over 16-bucket (pad with 0) -> logits at position 8.
    let mut padded = toks9.clone();
    padded.resize(16, 0);
    let t16 = literal_i32(&padded, &[1, 16]).unwrap();
    let outs = rt.execute("prefill_s16", &[&t16]).unwrap();
    let full_logits = to_vec_f32(&outs[0]).unwrap();
    let want = &full_logits[8 * vocab..9 * vocab];

    // Prefill the first 8, insert KV into a dense cache, decode token 8.
    let mut p8 = toks9[..8].to_vec();
    p8.resize(16, 0);
    let t8 = literal_i32(&p8, &[1, 16]).unwrap();
    let outs8 = rt.execute("prefill_s16", &[&t8]).unwrap();
    let k8 = to_vec_f32(&outs8[1]).unwrap(); // [Lyr,1,H,16,Dh]
    let v8 = to_vec_f32(&outs8[2]).unwrap();

    let (_, _, shape) = zero_cache(&rt, 1);
    let n: usize = shape.iter().product();
    let mut kd = vec![0.0f32; n];
    let mut vd = vec![0.0f32; n];
    // copy [Lyr,1,H,8,Dh] into [Lyr,1,H,max_seq,Dh]
    let (lyr, h, dh, ms) = (m.n_layers, m.n_heads, m.head_dim, m.max_seq);
    for l in 0..lyr {
        for hh in 0..h {
            for t in 0..8 {
                let src = ((l * h + hh) * 16 + t) * dh;
                let dst = ((l * h + hh) * ms + t) * dh;
                kd[dst..dst + dh].copy_from_slice(&k8[src..src + dh]);
                vd[dst..dst + dh].copy_from_slice(&v8[src..src + dh]);
            }
        }
    }
    let kc = literal_f32(&kd, &shape).unwrap();
    let vc = literal_f32(&vd, &shape).unwrap();
    let toks = literal_i32(&[toks9[8]], &[1]).unwrap();
    let pos = literal_i32(&[8], &[1]).unwrap();
    let dec = rt.execute("decode_b1", &[&toks, &pos, &kc, &vc]).unwrap();
    let got = to_vec_f32(&dec[0]).unwrap();
    let d = max_abs_diff(&got, want);
    assert!(d < 5e-3, "decode-continues-prefill mismatch: {d}");
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn decode_is_deterministic() {
    let Some(mut rt) = runtime() else { return };
    let (kc, vc, _) = zero_cache(&rt, 2);
    let toks = literal_i32(&[1, 2], &[2]).unwrap();
    let pos = literal_i32(&[0, 0], &[2]).unwrap();
    let a = rt.execute("decode_b2", &[&toks, &pos, &kc, &vc]).unwrap();
    let b = rt.execute("decode_b2", &[&toks, &pos, &kc, &vc]).unwrap();
    assert_eq!(to_vec_f32(&a[0]).unwrap(), to_vec_f32(&b[0]).unwrap());
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn manifest_entries_well_formed() {
    let Some(rt) = runtime() else { return };
    let man = &rt.manifest;
    assert!(man.entries.len() >= 10);
    for e in &man.entries {
        assert!(e.num_outputs >= 1, "{}", e.name);
        assert!(!e.inputs.is_empty(), "{}", e.name);
        assert!(
            std::path::Path::new("artifacts").join(&e.file).exists(),
            "missing HLO file for {}",
            e.name
        );
    }
    // naming convention helpers resolve
    assert!(man.entry(&Manifest::decode_entry_name(1, false)).is_ok());
    assert!(man.entry(&Manifest::prefill_entry_name(16)).is_ok());
    // the four Fig 9(a) shapes are recorded
    assert_eq!(man.linear_shapes.len(), 4);
}

#[test]
#[ignore = "requires make artifacts (PJRT + Pallas)"]
fn recompute_flags_stay_zero_on_normal_inputs() {
    let Some(mut rt) = runtime() else { return };
    let (kc, vc, _) = zero_cache(&rt, 1);
    let toks = literal_i32(&[100], &[1]).unwrap();
    let pos = literal_i32(&[0], &[1]).unwrap();
    let outs = rt.execute("decode_b1", &[&toks, &pos, &kc, &vc]).unwrap();
    let flags = to_vec_f32(&outs[3]).unwrap();
    assert!(flags.iter().all(|&f| f == 0.0), "unexpected recompute: {flags:?}");
}
