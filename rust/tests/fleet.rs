//! Fleet-layer integration tests: N=1 transparency (a fleet of one is
//! byte-identical to a bare engine over the full simtest seed matrix),
//! multi-replica scenarios under all five oracles, replica-kill
//! scenarios (mid-stream death, resubmission to survivors, nothing
//! lost or duplicated), and byte-identical reproduction of every fleet
//! run. See `fdpp::fleet` and docs/ARCHITECTURE.md § "Fleet serving".

use fdpp::api::{GenRequest, InferenceEngine};
use fdpp::config::{EngineConfig, FleetConfig, RoutePolicy};
use fdpp::fleet::{Fleet, ReplicaHealth};
use fdpp::simengine::SimSpec;
use fdpp::simtest::{run_replica_kill, run_replica_kill_sharded, run_scenario, run_scenario_fleet};

/// The same fixed matrix `sim_scenarios.rs` runs.
const SEED_MATRIX: std::ops::RangeInclusive<u64> = 1..=24;

#[test]
fn fleet_of_one_is_fingerprint_identical_to_bare_engine_on_the_matrix() {
    let mut failures = Vec::new();
    for seed in SEED_MATRIX {
        let bare = match run_scenario(seed) {
            Ok(r) => r,
            Err(v) => {
                eprintln!("{v}");
                failures.push(seed);
                continue;
            }
        };
        match run_scenario_fleet(seed, 1) {
            Ok(fleet) => {
                if bare != fleet {
                    eprintln!(
                        "seed {seed}: bare fp {:016x} != fleet fp {:016x}",
                        bare.fingerprint, fleet.fingerprint
                    );
                    failures.push(seed);
                }
            }
            Err(v) => {
                eprintln!("{v}");
                failures.push(seed);
            }
        }
    }
    assert!(
        failures.is_empty(),
        "fleet-of-one transparency broken on seeds: {failures:?}"
    );
}

#[test]
fn multi_replica_matrix_passes_all_oracles_and_reproduces() {
    let mut failures = Vec::new();
    let mut tokens = 0u64;
    for seed in SEED_MATRIX {
        match run_scenario_fleet(seed, 3) {
            Ok(a) => {
                tokens += a.tokens_generated;
                let b = run_scenario_fleet(seed, 3).expect("second run passes");
                assert_eq!(a, b, "seed {seed} must reproduce byte-identically");
            }
            Err(v) => {
                eprintln!("{v}");
                failures.push(seed);
            }
        }
    }
    assert!(failures.is_empty(), "failing seeds: {failures:?}");
    assert!(tokens > 100, "matrix generated {tokens} tokens");
}

#[test]
fn replica_kill_matrix_passes_all_oracles_and_reproduces() {
    let mut failures = Vec::new();
    for seed in SEED_MATRIX {
        for n_replicas in [2usize, 3] {
            match run_replica_kill(seed, n_replicas) {
                Ok(a) => {
                    let b = run_replica_kill(seed, n_replicas).expect("second run passes");
                    assert_eq!(
                        a, b,
                        "seed {seed} n={n_replicas} must reproduce byte-identically"
                    );
                }
                Err(v) => {
                    eprintln!("n_replicas {n_replicas}: {v}");
                    failures.push((seed, n_replicas));
                }
            }
        }
    }
    assert!(failures.is_empty(), "failing (seed, n): {failures:?}");
}

/// Composition: a fleet of *sharded* replicas (N=2 replicas, M=2 lanes
/// each) runs the replica-kill scenario under all five oracles, must
/// reproduce byte-identically, and — because sharding is invisible to
/// scheduling — must match the plain sim fleet's report byte for byte,
/// `set_seq_id_base` re-basing and all.
#[test]
fn sharded_fleet_composes_under_kill_and_reproduces() {
    let mut failures = Vec::new();
    for seed in SEED_MATRIX {
        match run_replica_kill_sharded(seed, 2, 2) {
            Ok(a) => {
                let b = run_replica_kill_sharded(seed, 2, 2).expect("second run passes");
                assert_eq!(a, b, "seed {seed} must reproduce byte-identically");
                let plain = run_replica_kill(seed, 2).expect("plain fleet passes");
                if a != plain {
                    eprintln!(
                        "seed {seed}: sharded fleet fp {:016x} != plain fleet fp {:016x}",
                        a.fingerprint, plain.fingerprint
                    );
                    failures.push(seed);
                }
            }
            Err(v) => {
                eprintln!("{v}");
                failures.push(seed);
            }
        }
    }
    assert!(
        failures.is_empty(),
        "sharded fleet composition broken on seeds: {failures:?}"
    );
}

/// Mid-stream kill at the engine-API level: partially streamed
/// requests restart on a survivor and finish exactly once.
#[test]
fn killed_replica_requests_finish_exactly_once_on_survivors() {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 128,
        max_new_tokens: 12,
        prefix_cache: true,
        ..EngineConfig::default()
    };
    let fcfg = FleetConfig {
        n_replicas: 3,
        policy: RoutePolicy::RoundRobin,
        ..FleetConfig::default()
    };
    let mut fleet = Fleet::sim(cfg, fcfg, SimSpec::default()).unwrap();
    let mut handles = Vec::new();
    for i in 0..6 {
        let req = GenRequest::text(format!("request number {i}")).max_new_tokens(12);
        handles.push(fleet.submit(req).unwrap());
    }
    // Let everything admit and stream a little, then kill replica 1.
    for _ in 0..3 {
        fleet.step().unwrap();
    }
    let moved = fleet.kill(1).unwrap();
    assert_eq!(moved.len(), 2, "round-robin put two requests on replica 1");
    assert_eq!(fleet.health(1), Some(ReplicaHealth::Dead));
    fleet.run_to_completion().unwrap();
    // Every surviving original handle finishes exactly once...
    let mut finished = 0;
    for h in &handles {
        let (_, fin) = h.drain();
        if fin.is_some() {
            finished += 1;
        }
    }
    assert_eq!(finished, 4, "the four requests on survivors finish");
    // ...and every resubmitted victim finishes exactly once too.
    for (_, h) in &moved {
        let (toks, fin) = h.drain();
        assert!(fin.is_some(), "resubmitted request finished");
        assert!(!toks.is_empty(), "resubmitted request streamed tokens");
    }
    // 4 survivors' originals + 2 re-runs; the dead replica's two
    // never finished (their tokens restarted on the survivors).
    assert_eq!(fleet.metrics().requests_finished, 6);
    assert_eq!(fleet.resubmitted(), 2);
}
