# AOT bridge: lower every L2 entry point to HLO *text* + export weights.
#
# HLO text (not .serialize()) is the interchange format: jax >= 0.5 emits
# HloModuleProto with 64-bit instruction ids which the xla crate's
# xla_extension 0.5.1 rejects; the text parser reassigns ids and
# round-trips cleanly (see /opt/xla-example/README.md).
#
# Outputs, under --out-dir (default ../artifacts):
#   manifest.json          model config + entry/weight inventory
#   weights/<name>.npy     one f32 .npy per weight tensor (Literal::read_npy)
#   <entry>.hlo.txt        one HLO module per entry point
#
# Entry points (all return tuples; rust unwraps with decompose_tuple):
#   decode_b{B}            async-softmax decode step, batch bucket B
#   decode_b{B}_sync       synchronized-softmax baseline decode step
#   decode_b{B}_jnpattn    oracle-attention decode step (test reference)
#   prefill_s{S}           single-sequence prefill, length bucket S
#   prefill_scores_s{S}    prefill that also returns QK^T scores (Fig. 5)
#   micro_{impl}_m{M}_{op} ImplA/B/C microkernels for the §5 decision flow
import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

DECODE_BATCHES = (1, 2, 4, 8)
SYNC_BATCHES = (1, 8)
PREFILL_SEQS = (16, 32, 64)
SCORES_SEQ = 64
MAX_SEQ = 256  # decode KV bucket (Lmax)

MICRO_MS = (1, 4, 8, 32, 64)
MICRO_IMPLS = ("gemv", "flat", "conv")
MICRO_OPS = ("qkv_proj", "ffn1")  # two of the four Fig. 9(a) shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec_of(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def choose_phi(cfg, ws, seq=48, n_prompts=4, seed=7):
    """Fig. 5 statistic: run prefill on sample prompts, collect the
    softmax-input distribution, and pick the unified scaling factor phi
    plus the safe window margin check (paper §3)."""
    xs = []
    key = jax.random.PRNGKey(seed)
    for i in range(n_prompts):
        key, sub = jax.random.split(key)
        toks = jax.random.randint(sub, (1, seq), 0, cfg.vocab_size)
        _, _, _, scores = M.prefill(cfg, ws, toks, return_scores=True)
        # keep only causal-valid entries
        mask = np.tril(np.ones((seq, seq), bool))
        xs.append(np.asarray(scores)[:, :, mask].ravel())
    x = np.concatenate(xs)
    stats = {
        "min": float(x.min()), "max": float(x.max()),
        "mean": float(x.mean()), "std": float(x.std()),
        "p01": float(np.percentile(x, 1)),
        "p999": float(np.percentile(x, 99.9)),
        "count": int(x.size),
    }
    # phi centers the observed range; the (a, b) window must cover the
    # observed extremes with margin, else the engine disables C1 (the
    # paper's OPT-6.7B rule).
    phi = float(np.median(x))
    return phi, stats


def build_entries(cfg, ws):
    """Yield (name, lowered, kind, params, input_specs) per entry point."""
    wlist = M.weights_list(ws)
    wspecs = [jax.ShapeDtypeStruct(w.shape, w.dtype) for w in wlist]
    h, dh, lyr = cfg.n_heads, cfg.head_dim, cfg.n_layers

    def decode_fn(attn, impl):
        def fn(*args):
            n = len(M.WEIGHT_ORDER)
            ws_d = M.weights_dict(args[:n])
            tokens, pos, kc, vc = args[n:]
            return M.decode_step(cfg, ws_d, tokens, pos, kc, vc,
                                 impl=impl, attn=attn)
        return fn

    for b in DECODE_BATCHES:
        impl = "gemv" if b == 1 else "flat"  # build-time lookup-table choice
        cache = jax.ShapeDtypeStruct((lyr, b, h, MAX_SEQ, dh), jnp.float32)
        ins = wspecs + [
            jax.ShapeDtypeStruct((b,), jnp.int32),   # tokens
            jax.ShapeDtypeStruct((b,), jnp.int32),   # pos
            cache, cache,
        ]
        variants = [("", "async", impl)]
        if b in SYNC_BATCHES:
            variants.append(("_sync", "sync", impl))
            variants.append(("_jnpattn", "jnp", "jnp"))
        for suffix, attn, impl_ in variants:
            name = f"decode_b{b}{suffix}"
            lowered = jax.jit(decode_fn(attn, impl_)).lower(*ins)
            yield (name, lowered, "decode",
                   {"batch": b, "max_seq": MAX_SEQ, "attn": attn,
                    "impl": impl_}, ins)

    def prefill_fn(return_scores):
        def fn(*args):
            n = len(M.WEIGHT_ORDER)
            ws_d = M.weights_dict(args[:n])
            (tokens,) = args[n:]
            return M.prefill(cfg, ws_d, tokens, return_scores=return_scores)
        return fn

    for s in PREFILL_SEQS:
        ins = wspecs + [jax.ShapeDtypeStruct((1, s), jnp.int32)]
        lowered = jax.jit(prefill_fn(False)).lower(*ins)
        yield (f"prefill_s{s}", lowered, "prefill", {"seq": s}, ins)

    ins = wspecs + [jax.ShapeDtypeStruct((1, SCORES_SEQ), jnp.int32)]
    lowered = jax.jit(prefill_fn(True)).lower(*ins)
    yield (f"prefill_scores_s{SCORES_SEQ}", lowered, "scores",
           {"seq": SCORES_SEQ}, ins)

    # Device-side KV insertion (perf pass, EXPERIMENTS.md §Perf): when a
    # freshly prefilled sequence joins a running decode batch, the engine
    # splices its KV into the dense cache *on device* instead of a full
    # host gather/scatter round trip.
    def insert_fn(kcache, vcache, k_new, v_new, lane):
        start = (jnp.int32(0), lane[0], jnp.int32(0), jnp.int32(0),
                 jnp.int32(0))
        kc = jax.lax.dynamic_update_slice(kcache, k_new, start)
        vc = jax.lax.dynamic_update_slice(vcache, v_new, start)
        return kc, vc

    for b in DECODE_BATCHES:
        cache = jax.ShapeDtypeStruct((lyr, b, h, MAX_SEQ, dh), jnp.float32)
        for s in PREFILL_SEQS:
            kv_new = jax.ShapeDtypeStruct((lyr, 1, h, s, dh), jnp.float32)
            ins = [cache, cache, kv_new, kv_new,
                   jax.ShapeDtypeStruct((1,), jnp.int32)]
            lowered = jax.jit(insert_fn).lower(*ins)
            yield (f"insert_b{b}_s{s}", lowered, "insert",
                   {"batch": b, "seq": s}, ins)

    shapes = cfg.linear_shapes()
    for op in MICRO_OPS:
        n, k = shapes[op]
        for impl in MICRO_IMPLS:
            for m in MICRO_MS:
                ins = [jax.ShapeDtypeStruct((m, k), jnp.float32),
                       jax.ShapeDtypeStruct((k, n), jnp.float32)]
                fn = M.micro_gemm(impl)
                lowered = jax.jit(lambda x, w, _f=fn: (_f(x, w),)).lower(*ins)
                yield (f"micro_{impl}_m{m}_{op}", lowered, "micro",
                       {"impl": impl, "m": m, "n": n, "k": k, "op": op}, ins)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-micro", action="store_true",
                    help="skip microkernel entries (faster CI builds)")
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(os.path.join(out, "weights"), exist_ok=True)

    cfg = M.TINY
    ws = M.init_weights(cfg, seed=args.seed)

    phi, stats = choose_phi(cfg, ws)
    cfg = M.ModelConfig(**{**cfg.__dict__, "phi": phi})
    print(f"phi={phi:.4f} softmax-input stats: {stats}")

    weights_meta = []
    for name in M.WEIGHT_ORDER:
        arr = np.asarray(ws[name], np.float32)
        np.save(os.path.join(out, "weights", f"{name}.npy"), arr)
        weights_meta.append({"name": name, "shape": list(arr.shape),
                             "dtype": "float32", "file": f"weights/{name}.npy"})

    entries = []
    for name, lowered, kind, params, ins in build_entries(cfg, ws):
        if args.skip_micro and kind == "micro":
            continue
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out, fname), "w") as f:
            f.write(text)
        n_out = len(jax.tree_util.tree_leaves(lowered.out_info))
        entries.append({
            "name": name, "file": fname, "kind": kind, "params": params,
            "inputs": [spec_of(s) for s in ins],
            "num_outputs": n_out,
            "takes_weights": kind not in ("micro", "insert"),
        })
        print(f"  {name}: {len(text)//1024} KiB, {n_out} outputs")

    manifest = {
        "model": {
            "name": cfg.name, "vocab_size": cfg.vocab_size, "dim": cfg.dim,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim, "ffn_hidden": cfg.ffn_hidden,
            "max_seq": MAX_SEQ,
            "phi": cfg.phi, "softmax_a": cfg.softmax_a,
            "softmax_b": cfg.softmax_b,
        },
        "softmax_input_stats": stats,
        "weight_order": M.WEIGHT_ORDER,
        "weights": weights_meta,
        "entries": entries,
        "linear_shapes": {k: list(v) for k, v in cfg.linear_shapes().items()},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} entries + manifest to {out}")


if __name__ == "__main__":
    main()
