# C1 — Asynchronized softmax with unified max value (paper §3).
#
# Decode-phase attention: one query token per (batch, head) against a KV
# cache of length L, processed in chunks of `block_l` along L.
#
# The paper's scheme: every chunk j computes
#     acc_j = sum_i e^{x_i - phi} * v_i        (numerator partial)
#     den_j = sum_i e^{x_i - phi}              (denominator partial)
# with a *unified* scaling factor phi, so chunks never exchange their
# running max (no synchronized update, Figure 4(c)). If any x_i - phi
# leaves the safe window (a, b), the row is *recomputed* with the
# synchronized online-softmax scheme (Figure 4(b) / Eq. 2).
#
# jit-friendly adaptation: inside one pass over KV we track BOTH the
# unified accumulators and the synchronized (online-softmax) accumulators,
# then select per row at the end. Under `jax.jit` a data-dependent relaunch
# is not expressible, and computing both tracks is the standard
# select-don't-branch mapping; on a real TPU deployment the synchronized
# track is the fallback kernel the paper relaunches. The per-row selector
# is exported as `recompute_flag` so the engine can account the paper's
# "recompute rate" (§3, negligible by Figure 5's statistics).
#
# Grid: (B, H, L / block_l) with the chunk dimension innermost/sequential —
# the accumulators live in VMEM scratch carried across chunk steps, which
# is the schedule Mosaic double-buffers on real hardware.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1e30  # finite stand-in for -inf (keeps exp()/max() NaN-free)


def _kernel(q_ref, k_ref, v_ref, kvlen_ref, o_ref, flag_ref,
            accu_ref, denu_ref, accs_ref, dens_ref, m_ref,
            *, scale, phi, a, b, block_l, num_chunks):
    chunk = pl.program_id(2)
    q = q_ref[0, 0, :].astype(jnp.float32)            # [D]
    k = k_ref[0, 0, :, :].astype(jnp.float32)         # [block_l, D]
    v = v_ref[0, 0, :, :].astype(jnp.float32)         # [block_l, D]
    kv_len = kvlen_ref[0]

    @pl.when(chunk == 0)
    def _init():
        accu_ref[...] = jnp.zeros_like(accu_ref)
        denu_ref[...] = jnp.zeros_like(denu_ref)
        accs_ref[...] = jnp.zeros_like(accs_ref)
        dens_ref[...] = jnp.zeros_like(dens_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)

    # x: softmax input row for this chunk, masked past kv_len.
    idx = chunk * block_l + jax.lax.iota(jnp.int32, block_l)
    x = jnp.dot(k, q) * scale                          # [block_l]
    valid = idx < kv_len
    x = jnp.where(valid, x, NEG_BIG)

    # --- unified-max track (asynchronized; no cross-chunk dependency) ---
    e_u = jnp.where(valid, jnp.exp(x - phi), 0.0)      # [block_l]
    accu_ref[0, :] += jnp.dot(e_u, v)                  # [D]
    denu_ref[0, 0] += jnp.sum(e_u)

    # --- synchronized track (online softmax, Eq. 2) — the fallback ---
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(x))
    corr = jnp.exp(m_prev - m_new)
    e_s = jnp.where(valid, jnp.exp(x - m_new), 0.0)
    accs_ref[0, :] = accs_ref[0, :] * corr + jnp.dot(e_s, v)
    dens_ref[0, 0] = dens_ref[0, 0] * corr + jnp.sum(e_s)
    m_ref[0, 0] = m_new

    @pl.when(chunk == num_chunks - 1)
    def _finalize():
        m = m_ref[0, 0]
        # Overflow/precision guard (§3 Approach: Recomputation): the row
        # must be recomputed when its true max leaves the window around phi.
        overflow = jnp.logical_or(m - phi > b, m - phi < a)
        o_u = accu_ref[0, :] / denu_ref[0, 0]
        o_s = accs_ref[0, :] / dens_ref[0, 0]
        o_ref[0, 0, :] = jnp.where(overflow, o_s, o_u).astype(o_ref.dtype)
        flag_ref[0, 0] = overflow.astype(jnp.float32)


def _pick_block_l(l, block_l):
    if l % block_l != 0:
        block_l = min(block_l, l)
        while l % block_l != 0:
            block_l //= 2
    return block_l


@functools.partial(
    jax.jit,
    static_argnames=("phi", "a", "b", "block_l", "scale", "interpret"),
)
def async_softmax_attention(q, k, v, kv_len, *, phi=0.0, a=-20.0, b=15.0,
                            block_l=128, scale=None, interpret=True):
    """Decode attention with the unified-max asynchronized softmax.

    q: [B, H, D]; k, v: [B, H, L, D]; kv_len: i32[B] (valid KV prefix
    per sequence — continuous batching mixes lengths).
    Returns (o: [B, H, D], recompute_flag: f32[B, H]).
    """
    batch, heads, d = q.shape
    l = k.shape[2]
    block_l = _pick_block_l(l, block_l)
    num_chunks = l // block_l
    if scale is None:
        scale = float(1.0 / (d ** 0.5))

    kernel = functools.partial(
        _kernel, scale=scale, phi=phi, a=a, b=b,
        block_l=block_l, num_chunks=num_chunks,
    )
    grid = (batch, heads, num_chunks)
    o, flag = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h, c: (b_, h, 0)),
            pl.BlockSpec((1, 1, block_l, d), lambda b_, h, c: (b_, h, c, 0)),
            pl.BlockSpec((1, 1, block_l, d), lambda b_, h, c: (b_, h, c, 0)),
            pl.BlockSpec((1,), lambda b_, h, c: (b_,)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, d), lambda b_, h, c: (b_, h, 0)),
            pl.BlockSpec((1, 1), lambda b_, h, c: (b_, h)),
        ),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),   # acc_u
            pltpu.VMEM((1, 1), jnp.float32),   # den_u
            pltpu.VMEM((1, d), jnp.float32),   # acc_s
            pltpu.VMEM((1, 1), jnp.float32),   # den_s
            pltpu.VMEM((1, 1), jnp.float32),   # running max m
        ],
        out_shape=(
            jax.ShapeDtypeStruct((batch, heads, d), q.dtype),
            jax.ShapeDtypeStruct((batch, heads), jnp.float32),
        ),
        interpret=interpret,
    )(q, k, v, kv_len)
    return o, flag
