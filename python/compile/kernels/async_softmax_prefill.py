# C1 applied to the prefill phase: causal tiled attention whose partial
# softmax uses the unified max value (paper §3 — the technique is not
# decode-specific; FlashAttention's synchronized rescale is what it
# replaces).
#
# Tiling: grid (B, H, Sq/block_q, Skv/block_kv) with the KV-block
# dimension innermost/sequential; per-(b,h,q-block) accumulators live in
# VMEM scratch carried across KV steps. Fully-masked KV blocks (above
# the causal diagonal) are skipped via pl.when — the schedule the paper's
# prefill kernel gets from its threadblock mapping.
#
# Like the decode kernel, both the unified-phi track and the
# online-softmax fallback track are computed and selected per row at
# finalize (jit-able overflow handling); the flag output reports the
# recompute rate.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, flag_ref,
            accu_ref, denu_ref, accs_ref, dens_ref, m_ref,
            *, scale, phi, a, b, block_q, block_kv, num_kv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        accu_ref[...] = jnp.zeros_like(accu_ref)
        denu_ref[...] = jnp.zeros_like(denu_ref)
        accs_ref[...] = jnp.zeros_like(accs_ref)
        dens_ref[...] = jnp.zeros_like(dens_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Skip KV blocks strictly above the causal diagonal.
    @pl.when(k_start <= q_start + block_q - 1)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)      # [block_q, D]
        k = k_ref[0, 0, :, :].astype(jnp.float32)      # [block_kv, D]
        v = v_ref[0, 0, :, :].astype(jnp.float32)      # [block_kv, D]
        x = jnp.dot(q, k.T) * scale                     # [block_q, block_kv]
        rows = q_start + jax.lax.iota(jnp.int32, block_q)[:, None]
        cols = k_start + jax.lax.iota(jnp.int32, block_kv)[None, :]
        causal = cols <= rows
        x = jnp.where(causal, x, NEG_BIG)

        # unified-max track (asynchronized)
        e_u = jnp.where(causal, jnp.exp(x - phi), 0.0)
        accu_ref[...] += jnp.dot(e_u, v)
        denu_ref[...] += jnp.sum(e_u, axis=1, keepdims=True)

        # synchronized online-softmax track (fallback)
        m_prev = m_ref[...]                             # [block_q, 1]
        m_new = jnp.maximum(m_prev, jnp.max(x, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        e_s = jnp.where(causal, jnp.exp(x - m_new), 0.0)
        accs_ref[...] = accs_ref[...] * corr + jnp.dot(e_s, v)
        dens_ref[...] = dens_ref[...] * corr + jnp.sum(e_s, axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finalize():
        m = m_ref[...]
        overflow = jnp.logical_or(m - phi > b, m - phi < a)  # [block_q, 1]
        o_u = accu_ref[...] / denu_ref[...]
        o_s = accs_ref[...] / dens_ref[...]
        o_ref[0, 0, :, :] = jnp.where(overflow, o_s, o_u).astype(o_ref.dtype)
        flag_ref[0, 0, :] = overflow[:, 0].astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("phi", "a", "b", "block_q", "block_kv", "scale",
                     "interpret"),
)
def async_softmax_prefill(q, k, v, *, phi=0.0, a=-25.0, b=18.0,
                          block_q=32, block_kv=64, scale=None,
                          interpret=True):
    """Causal self-attention with unified-max partial softmax.

    q, k, v: [B, H, S, D]. Returns (o [B, H, S, D], flags f32[B, H, S]).
    """
    batch, heads, s, d = q.shape
    block_q = min(block_q, s)
    while s % block_q != 0:
        block_q //= 2
    block_kv = min(block_kv, s)
    while s % block_kv != 0:
        block_kv //= 2
    num_kv = s // block_kv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))

    kernel = functools.partial(
        _kernel, scale=scale, phi=phi, a=a, b=b,
        block_q=block_q, block_kv=block_kv, num_kv=num_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=(batch, heads, s // block_q, num_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h, qi, ki: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h, qi, ki: (b_, h, ki, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, qi, ki: (b_, h, qi)),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc_u
            pltpu.VMEM((block_q, 1), jnp.float32),   # den_u
            pltpu.VMEM((block_q, d), jnp.float32),   # acc_s
            pltpu.VMEM((block_q, 1), jnp.float32),   # den_s
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
        ],
        out_shape=(
            jax.ShapeDtypeStruct((batch, heads, s, d), q.dtype),
            jax.ShapeDtypeStruct((batch, heads, s), jnp.float32),
        ),
        interpret=interpret,
    )(q, k, v)
