# ImplA — FastGEMV-style vector kernel (paper §5).
#
# For M in {1..4} the paper routes linear layers to CUDA-core GEMV
# (FastGEMV) rather than Tensor Cores: at these shapes the MAC array is
# almost entirely padding, and a bandwidth-bound vector kernel wins
# (cuBLAS-TC reaches only 82.15% of FastGEMV at M=1 on A100, §5).
#
# TPU adaptation: CUDA cores -> the VPU. The kernel deliberately avoids
# jnp.dot (MXU) and computes broadcast-multiply + K-reduction on vector
# lanes, mirroring FastGEMV's per-row dot products.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ceil_to(x, m):
    return (x + m - 1) // m * m


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, num_k):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)      # [M, block_k]
    w = w_ref[...].astype(jnp.float32)      # [block_k, block_n]
    # VPU path: broadcast multiply + reduce over K. No MXU contraction.
    acc_ref[...] += jnp.sum(x[:, :, None] * w[None, :, :], axis=1)

    @pl.when(kk == num_k - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "interpret"),
)
def gemv(x, w, *, block_n=128, block_k=256, interpret=True):
    """ImplA: [M, K] @ [K, N] via vector-unit dot products (M small).

    No M padding at all — each of the M rows is a genuine vector workload.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (k, k2)
    block_k = min(block_k, _ceil_to(k, 8))
    block_n = min(block_n, _ceil_to(n, 8))
    kp = _ceil_to(k, block_k)
    np_ = _ceil_to(n, block_n)
    xp = jnp.pad(x, ((0, 0), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    num_k = kp // block_k

    out = pl.pallas_call(
        functools.partial(_kernel, num_k=num_k),
        grid=(np_ // block_n, num_k),
        in_specs=[
            pl.BlockSpec((m, block_k), lambda nn, kk: (0, kk)),
            pl.BlockSpec((block_k, block_n), lambda nn, kk: (kk, nn)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda nn, kk: (0, nn)),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, np_), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:, :n]
