# Pure-jnp correctness oracles for the Pallas kernels.
#
# Everything here is the "textbook" computation (Figure 4(a) of the paper):
# numerically-stable softmax with the true max, dense attention, dense
# matmul. The kernels in this package must match these to ~1e-5 (f32).
import jax.numpy as jnp


def softmax_ref(x, axis=-1):
    """Numerically-stable softmax (Figure 4(a)): m(x), f(x), l(x)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    f = jnp.exp(x - m)
    return f / jnp.sum(f, axis=axis, keepdims=True)


def attention_decode_ref(q, k, v, scale=None, kv_len=None):
    """Single-token decode attention.

    q: [B, H, D]; k, v: [B, H, L, D]. Returns o: [B, H, D].
    If kv_len is given, positions >= kv_len are masked out.
    """
    d = q.shape[-1]
    if scale is None:
        scale = (1.0 / jnp.sqrt(d)).astype(q.dtype)
    # x: [B, H, L] — the softmax input row per (batch, head).
    x = jnp.einsum("bhd,bhld->bhl", q, k) * scale
    if kv_len is not None:
        idx = jnp.arange(k.shape[2])
        x = jnp.where(idx[None, None, :] < kv_len, x, -jnp.inf)
    p = softmax_ref(x, axis=-1)
    return jnp.einsum("bhl,bhld->bhd", p, v)


def attention_prefill_ref(q, k, v, scale=None):
    """Causal self-attention. q,k,v: [B, H, S, D] -> [B, H, S, D]."""
    d = q.shape[-1]
    if scale is None:
        scale = (1.0 / jnp.sqrt(d)).astype(q.dtype)
    s = q.shape[2]
    x = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    x = jnp.where(mask[None, None], x, -jnp.inf)
    p = softmax_ref(x, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v)


def matmul_ref(x, w):
    """[M, K] @ [K, N] in f32 accumulation."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def unified_softmax_attention_ref(q, k, v, phi, scale=None, kv_len=None):
    """Oracle for the *unified max value* path (Eq. 3/4 of the paper).

    Mathematically identical to attention_decode_ref for any phi (the
    scaling factor cancels); kept separate so tests can also check the
    intermediate accumulators' finiteness for in-range phi.
    """
    d = q.shape[-1]
    if scale is None:
        scale = (1.0 / jnp.sqrt(d)).astype(q.dtype)
    x = jnp.einsum("bhd,bhld->bhl", q, k) * scale
    if kv_len is not None:
        idx = jnp.arange(k.shape[2])
        x = jnp.where(idx[None, None, :] < kv_len, x, -jnp.inf)
    e = jnp.exp(x - phi)  # no per-row max: the unified scaling factor
    num = jnp.einsum("bhl,bhld->bhd", e, v)
    den = jnp.sum(e, axis=-1, keepdims=True)
    return num / den
