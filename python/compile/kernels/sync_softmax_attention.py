# Baseline — synchronized partial softmax (paper §2.3, Figure 4(b)).
#
# This is the FlashAttention/FlashDecoding scheme: each KV chunk computes a
# partial softmax with its own local max, and every new chunk *rescales*
# the previous accumulators by e^{m_prev - m_new} (Eq. 2 of the paper) —
# the synchronized update whose overhead (~18.8% of attention time on
# Llama2-7B/A100, §2.3) motivates C1. Used as the correctness fallback and
# the baseline for the claim_softmax_overhead bench.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1e30


def _kernel(q_ref, k_ref, v_ref, kvlen_ref, o_ref,
            acc_ref, den_ref, m_ref,
            *, scale, block_l, num_chunks):
    chunk = pl.program_id(2)
    q = q_ref[0, 0, :].astype(jnp.float32)
    k = k_ref[0, 0, :, :].astype(jnp.float32)
    v = v_ref[0, 0, :, :].astype(jnp.float32)
    kv_len = kvlen_ref[0]

    @pl.when(chunk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)

    idx = chunk * block_l + jax.lax.iota(jnp.int32, block_l)
    x = jnp.dot(k, q) * scale
    valid = idx < kv_len
    x = jnp.where(valid, x, NEG_BIG)

    # Synchronized update (Eq. 2): rescale previous partials by the new max.
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(x))
    corr = jnp.exp(m_prev - m_new)
    e = jnp.where(valid, jnp.exp(x - m_new), 0.0)
    acc_ref[0, :] = acc_ref[0, :] * corr + jnp.dot(e, v)
    den_ref[0, 0] = den_ref[0, 0] * corr + jnp.sum(e)
    m_ref[0, 0] = m_new

    @pl.when(chunk == num_chunks - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_ref[0, :] / den_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_l", "scale", "interpret"),
)
def sync_softmax_attention(q, k, v, kv_len, *, block_l=128, scale=None,
                           interpret=True):
    """Decode attention with the synchronized partial softmax (baseline).

    q: [B, H, D]; k, v: [B, H, L, D]; kv_len: i32[B]. Returns o: [B, H, D].
    """
    batch, heads, d = q.shape
    l = k.shape[2]
    if l % block_l != 0:
        block_l = min(block_l, l)
        while l % block_l != 0:
            block_l //= 2
    num_chunks = l // block_l
    if scale is None:
        scale = float(1.0 / (d ** 0.5))

    kernel = functools.partial(
        _kernel, scale=scale, block_l=block_l, num_chunks=num_chunks,
    )
    return pl.pallas_call(
        kernel,
        grid=(batch, heads, num_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h, c: (b_, h, 0)),
            pl.BlockSpec((1, 1, block_l, d), lambda b_, h, c: (b_, h, c, 0)),
            pl.BlockSpec((1, 1, block_l, d), lambda b_, h, c: (b_, h, c, 0)),
            pl.BlockSpec((1,), lambda b_, h, c: (b_,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h, c: (b_, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((batch, heads, d), q.dtype),
        interpret=interpret,
    )(q, k, v, kv_len)
