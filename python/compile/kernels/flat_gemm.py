# C2 — Flat GEMM optimization with double buffering (paper §4).
#
# Decode-phase linear layers multiply a *flat* activation [M, K] (M = batch
# size, usually <= 8) by a weight [K, N]. cuBLAS-era libraries tile M to 64
# and pad with zeros (>87% wasted MACs at M=8); FlashDecoding++ pads M only
# to the hardware's native GEMM granularity (8) and tiles N for parallelism
# and K sequentially for reuse.
#
# TPU adaptation (DESIGN.md §2): the native M granularity is the 8-sublane
# MXU tile, so pad-to-8 carries over directly. The paper's shared-memory
# double buffering maps to the Pallas schedule: the K loop is the
# innermost *sequential* grid dimension over BlockSpec-carried tiles, which
# Mosaic automatically double-buffers between HBM and VMEM; the accumulator
# lives in VMEM scratch. `flat_gemm` (ImplB) uses the MXU (jnp.dot);
# `conventional_gemm` (ImplC) adds M-tiling for big-M prefill GEMMs.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MIN_M_PAD = 8  # paper §4: pad M to 8 (Tensor-Core / MXU granularity), not 64


def _ceil_to(x, m):
    return (x + m - 1) // m * m


def _flat_kernel(x_ref, w_ref, o_ref, acc_ref, *, num_k):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)   # [Mp, block_k]
    w = w_ref[...].astype(jnp.float32)   # [block_k, block_n]
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kk == num_k - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "interpret"),
)
def flat_gemm(x, w, *, block_n=128, block_k=128, interpret=True):
    """ImplB: [M, K] @ [K, N] with M padded to 8 (not 64).

    Grid = (N / block_n) parallel x (K / block_k) sequential; f32 VMEM
    accumulator carried across the K steps.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (k, k2)
    mp = max(MIN_M_PAD, _ceil_to(m, MIN_M_PAD))
    block_k = min(block_k, _ceil_to(k, 8))
    block_n = min(block_n, _ceil_to(n, 8))
    kp = _ceil_to(k, block_k)
    np_ = _ceil_to(n, block_n)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    num_k = kp // block_k

    out = pl.pallas_call(
        functools.partial(_flat_kernel, num_k=num_k),
        grid=(np_ // block_n, num_k),
        in_specs=[
            pl.BlockSpec((mp, block_k), lambda nn, kk: (0, kk)),
            pl.BlockSpec((block_k, block_n), lambda nn, kk: (kk, nn)),
        ],
        out_specs=pl.BlockSpec((mp, block_n), lambda nn, kk: (0, nn)),
        scratch_shapes=[pltpu.VMEM((mp, block_n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, num_k):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kk == num_k - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def conventional_gemm(x, w, *, block_m=64, block_n=128, block_k=128,
                      interpret=True):
    """ImplC: conventionally tiled GEMM (M tiled to 64) for prefill shapes.

    This is the cuBLAS/CUTLASS-style schedule the paper keeps for large M;
    it is also the *baseline* whose zero-padding waste Fig. 10 exposes when
    misapplied to flat shapes.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (k, k2)
    block_k = min(block_k, _ceil_to(k, 8))
    block_n = min(block_n, _ceil_to(n, 8))
    mp = _ceil_to(max(m, block_m), block_m)
    kp = _ceil_to(k, block_k)
    np_ = _ceil_to(n, block_n)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    num_k = kp // block_k

    out = pl.pallas_call(
        functools.partial(_conv_kernel, num_k=num_k),
        grid=(mp // block_m, np_ // block_n, num_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mm, nn, kk: (mm, kk)),
            pl.BlockSpec((block_k, block_n), lambda mm, nn, kk: (kk, nn)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mm, nn, kk: (mm, nn)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]
