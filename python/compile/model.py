# L2 — the JAX model: a Llama-2-style decoder-only transformer whose
# decode path runs on the FlashDecoding++ kernels (C1 attention, C2/ImplA
# linear layers) and whose prefill path uses the conventional schedule the
# paper keeps for large-M shapes.
#
# Build-time only: `aot.py` lowers the entry points defined here to HLO
# text; the Rust engine executes them via PJRT. Python never serves.
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.async_softmax_attention import async_softmax_attention
from compile.kernels.async_softmax_prefill import async_softmax_prefill
from compile.kernels.flat_gemm import flat_gemm, conventional_gemm
from compile.kernels.gemv import gemv
from compile.kernels import ref


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (paper Table 2 shape, tiny scale)."""
    name: str = "llama2-tiny"
    vocab_size: int = 512          # byte-level tokens + specials, padded
    dim: int = 256
    n_layers: int = 4
    n_heads: int = 4
    ffn_hidden: int = 512          # SwiGLU inner width
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # C1 parameters: unified scaling factor and safe window (paper §3).
    phi: float = 0.0
    softmax_a: float = -25.0
    softmax_b: float = 18.0

    @property
    def head_dim(self):
        return self.dim // self.n_heads

    def linear_shapes(self):
        """The four [N, K] linear shapes of Figure 9(a), fused-QKV."""
        d, f = self.dim, self.ffn_hidden
        return {
            "qkv_proj": (3 * d, d),    # W_K,W_Q,W_V fused
            "o_proj": (d, d),
            "ffn1": (2 * f, d),        # gate+up fused
            "ffn2": (d, f),
        }


TINY = ModelConfig()

# Paper Table 2 configurations (consumed by the Rust analytic hwmodel; ffn
# widths from the public model cards).
PAPER_CONFIGS = {
    "llama2-7b": dict(vocab_size=32000, dim=4096, n_layers=32, n_heads=32,
                      ffn_hidden=11008, context=4096),
    "llama2-13b": dict(vocab_size=32000, dim=5120, n_layers=40, n_heads=40,
                       ffn_hidden=13824, context=4096),
    "opt-6.7b": dict(vocab_size=50272, dim=4096, n_layers=32, n_heads=32,
                     ffn_hidden=16384, context=2048),
    "chatglm2-6b": dict(vocab_size=65024, dim=4096, n_layers=28, n_heads=32,
                        ffn_hidden=13696, context=32768),
}


# --------------------------------------------------------------------------
# Weights
# --------------------------------------------------------------------------

WEIGHT_ORDER = [
    "embed",       # [V, D]
    "wqkv",        # [L, D, 3D]
    "wo",          # [L, D, D]
    "w13",         # [L, D, 2F]  (gate+up fused)
    "w2",          # [L, F, D]
    "ln1",         # [L, D]
    "ln2",         # [L, D]
    "ln_f",        # [D]
    "lm_head",     # [D, V]
]


def weight_shapes(cfg: ModelConfig):
    d, f, l, v = cfg.dim, cfg.ffn_hidden, cfg.n_layers, cfg.vocab_size
    return {
        "embed": (v, d),
        "wqkv": (l, d, 3 * d),
        "wo": (l, d, d),
        "w13": (l, d, 2 * f),
        "w2": (l, f, d),
        "ln1": (l, d),
        "ln2": (l, d),
        "ln_f": (d,),
        "lm_head": (d, v),
    }


def init_weights(cfg: ModelConfig, seed: int = 0):
    """Deterministic synthetic weights, scaled for stable logits."""
    key = jax.random.PRNGKey(seed)
    ws = {}
    for name, shape in weight_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.startswith("ln"):
            ws[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            ws[name] = (jax.random.normal(sub, shape, jnp.float32)
                        * (1.0 / jnp.sqrt(fan_in)))
    return ws


def weights_list(ws):
    return [ws[n] for n in WEIGHT_ORDER]


def weights_dict(args):
    return dict(zip(WEIGHT_ORDER, args))


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rmsnorm(x, g, eps):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


def rope(x, pos, theta):
    """Rotary embedding. x: [..., H, Dh]; pos: [...] (one per leading dim)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = pos[..., None].astype(jnp.float32) * freqs   # [..., half]
    cos = jnp.cos(angles)[..., None, :]                    # [..., 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


def _linear_decode(x, w, impl, interpret):
    """Flat linear for decode: x [B, K] @ w [K, N] routed per ImplKind."""
    if impl == "gemv":
        return gemv(x, w, interpret=interpret)
    if impl == "flat":
        return flat_gemm(x, w, interpret=interpret)
    if impl == "conv":
        return conventional_gemm(x, w, interpret=interpret)
    if impl == "jnp":
        return ref.matmul_ref(x, w)
    raise ValueError(impl)


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, ws, tokens, pos, kcache, vcache, *,
                impl="flat", attn="async", interpret=True):
    """One decode step for a batch of sequences.

    tokens: i32[B]; pos: i32[B] (write position per sequence, 0-based);
    kcache/vcache: f32[Lyr, B, H, Lmax, Dh].
    Returns (logits f32[B, V], kcache, vcache, recompute_flags f32[B]).
    """
    b = tokens.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    x = ws["embed"][tokens]                      # [B, D]
    kv_len = (pos + 1).astype(jnp.int32)         # valid prefix per sequence
    batch_idx = jnp.arange(b)

    def layer(x, layer_ws):
        wqkv, wo, w13, w2, ln1, ln2, kc, vc = layer_ws
        xn = rmsnorm(x, ln1, cfg.norm_eps)
        qkv = _linear_decode(xn, wqkv, impl, interpret)   # [B, 3D]
        q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
        q = rope(q.reshape(b, h, dh), pos, cfg.rope_theta)
        k_new = rope(k_new.reshape(b, h, dh), pos, cfg.rope_theta)
        v_new = v_new.reshape(b, h, dh)
        # scatter the new token into the cache at its per-sequence position
        kc = kc.at[batch_idx, :, pos, :].set(k_new)       # [B, H, Lmax, Dh]
        vc = vc.at[batch_idx, :, pos, :].set(v_new)
        if attn == "async":
            o, flags = async_softmax_attention(
                q, kc, vc, kv_len, phi=cfg.phi,
                a=cfg.softmax_a, b=cfg.softmax_b, interpret=interpret)
        elif attn == "sync":
            from compile.kernels.sync_softmax_attention import (
                sync_softmax_attention)
            o = sync_softmax_attention(q, kc, vc, kv_len, interpret=interpret)
            flags = jnp.zeros((b, h), jnp.float32)
        else:  # pure-jnp reference attention (oracle path)
            o = jax.vmap(lambda qq, kk, vv, n: ref.attention_decode_ref(
                qq[None], kk[None], vv[None], kv_len=n)[0],
                in_axes=(0, 0, 0, 0))(q, kc, vc, kv_len)
            flags = jnp.zeros((b, h), jnp.float32)
        o = _linear_decode(o.reshape(b, h * dh), wo, impl, interpret)
        x = x + o
        xn = rmsnorm(x, ln2, cfg.norm_eps)
        gu = _linear_decode(xn, w13, impl, interpret)     # [B, 2F]
        g, u = jnp.split(gu, 2, axis=-1)
        y = _linear_decode(jax.nn.silu(g) * u, w2, impl, interpret)
        x = x + y
        return x, (kc, vc, jnp.max(flags, axis=-1))

    # Unrolled layer loop (n_layers is small; lets XLA fuse across layers).
    kcs, vcs, flags = [], [], []
    for li in range(cfg.n_layers):
        x, (kc, vc, fl) = layer(
            x, (ws["wqkv"][li], ws["wo"][li], ws["w13"][li], ws["w2"][li],
                ws["ln1"][li], ws["ln2"][li], kcache[li], vcache[li]))
        kcs.append(kc)
        vcs.append(vc)
        flags.append(fl)
    x = rmsnorm(x, ws["ln_f"], cfg.norm_eps)
    logits = ref.matmul_ref(x, ws["lm_head"])             # [B, V]
    return (logits, jnp.stack(kcs), jnp.stack(vcs),
            jnp.max(jnp.stack(flags), axis=0))


def prefill(cfg: ModelConfig, ws, tokens, *, interpret=True,
            return_scores=False, attn="pallas"):
    """Prefill a single sequence. tokens: i32[1, S].

    Returns (logits f32[S, V] for every position — the engine pads
    prompts up to the bucket length and reads row len-1,
    k f32[Lyr, 1, H, S, Dh], v likewise[, scores f32[Lyr, H, S, S]]).
    """
    _, s = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim
    x = ws["embed"][tokens[0]]                    # [S, D]
    pos = jnp.arange(s)
    scale = 1.0 / (dh ** 0.5)
    ks, vs, scores_all = [], [], []

    for li in range(cfg.n_layers):
        xn = rmsnorm(x, ws["ln1"][li], cfg.norm_eps)
        qkv = ref.matmul_ref(xn, ws["wqkv"][li])  # [S, 3D] — ImplC regime
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = rope(q.reshape(s, h, dh), pos, cfg.rope_theta)
        k = rope(k.reshape(s, h, dh), pos, cfg.rope_theta)
        v = v.reshape(s, h, dh)
        qh = q.transpose(1, 0, 2)[None]           # [1, H, S, Dh]
        kh = k.transpose(1, 0, 2)[None]
        vh = v.transpose(1, 0, 2)[None]
        if return_scores:
            sc = jnp.einsum("bhsd,bhtd->bhst", qh, kh) * scale
            scores_all.append(sc[0])
        if attn == "pallas":
            # C1 for prefill: unified-max causal attention kernel.
            o, _ = async_softmax_prefill(
                qh, kh, vh, phi=cfg.phi, a=cfg.softmax_a, b=cfg.softmax_b,
                interpret=interpret)
        else:
            o = ref.attention_prefill_ref(qh, kh, vh)  # causal oracle
        o = o[0].transpose(1, 0, 2).reshape(s, h * dh)
        x = x + ref.matmul_ref(o, ws["wo"][li])
        xn = rmsnorm(x, ws["ln2"][li], cfg.norm_eps)
        g, u = jnp.split(ref.matmul_ref(xn, ws["w13"][li]), 2, axis=-1)
        x = x + ref.matmul_ref(jax.nn.silu(g) * u, ws["w2"][li])
        ks.append(kh)
        vs.append(vh)

    xf = rmsnorm(x, ws["ln_f"], cfg.norm_eps)
    logits = ref.matmul_ref(xf, ws["lm_head"])    # [S, V]
    k_out = jnp.stack(ks)                         # [Lyr, 1, H, S, Dh]
    v_out = jnp.stack(vs)
    if return_scores:
        return logits, k_out, v_out, jnp.stack(scores_all)
    return logits, k_out, v_out


def micro_gemm(impl, *, interpret=True):
    """Microkernel entry for the §5 decision flow: fn(x[m,k], w[k,n])."""
    def fn(x, w):
        return _linear_decode(x, w, impl, interpret)
    return fn
