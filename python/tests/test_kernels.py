# L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.async_softmax_attention import async_softmax_attention
from compile.kernels.sync_softmax_attention import sync_softmax_attention
from compile.kernels.flat_gemm import flat_gemm, conventional_gemm
from compile.kernels.gemv import gemv
from compile.kernels import ref


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# C1: asynchronized softmax attention
# ---------------------------------------------------------------------------

class TestAsyncSoftmaxAttention:
    @pytest.mark.parametrize("b,h,l,d", [
        (1, 1, 128, 64), (2, 4, 256, 64), (1, 4, 512, 32),
        (4, 2, 256, 128), (8, 4, 128, 64),
    ])
    def test_matches_oracle(self, b, h, l, d):
        q = rand(0, (b, h, d))
        k = rand(1, (b, h, l, d))
        v = rand(2, (b, h, l, d))
        kv_len = jnp.full((b,), l, jnp.int32)
        o, flags = async_softmax_attention(q, k, v, kv_len)
        want = ref.attention_decode_ref(q, k, v, kv_len=l)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)
        assert float(flags.sum()) == 0.0  # unit-scale inputs: no recompute

    @pytest.mark.parametrize("kv_len", [1, 7, 100, 129, 255, 256])
    def test_masking_partial_kv(self, kv_len):
        b, h, l, d = 2, 2, 256, 64
        q = rand(3, (b, h, d))
        k = rand(4, (b, h, l, d))
        v = rand(5, (b, h, l, d))
        lens = jnp.full((b,), kv_len, jnp.int32)
        o, _ = async_softmax_attention(q, k, v, lens)
        want = ref.attention_decode_ref(q, k, v, kv_len=kv_len)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)

    def test_per_sequence_kv_len(self):
        """Continuous batching: every sequence has its own valid prefix."""
        b, h, l, d = 4, 2, 128, 64
        q = rand(6, (b, h, d))
        k = rand(7, (b, h, l, d))
        v = rand(8, (b, h, l, d))
        lens = jnp.array([1, 33, 100, 128], jnp.int32)
        o, _ = async_softmax_attention(q, k, v, lens)
        for i, n in enumerate([1, 33, 100, 128]):
            want = ref.attention_decode_ref(
                q[i:i+1], k[i:i+1], v[i:i+1], kv_len=n)
            np.testing.assert_allclose(o[i:i+1], want, atol=2e-5, rtol=2e-5)

    def test_overflow_triggers_recompute_path(self):
        """Rows whose max leaves (a, b) must fall back (paper §3) and
        still be exact."""
        b, h, l, d = 2, 4, 256, 64
        q = rand(9, (b, h, d), scale=40.0)  # huge logits -> m - phi > b
        k = rand(10, (b, h, l, d))
        v = rand(11, (b, h, l, d))
        lens = jnp.full((b,), l, jnp.int32)
        o, flags = async_softmax_attention(q, k, v, lens, phi=0.0, b=15.0)
        want = ref.attention_decode_ref(q, k, v, kv_len=l)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)
        assert float(flags.sum()) > 0  # at least one row recomputed

    def test_phi_invariance(self):
        """Eq. 3: any in-range phi gives the same softmax."""
        b, h, l, d = 1, 2, 128, 64
        q = rand(12, (b, h, d))
        k = rand(13, (b, h, l, d))
        v = rand(14, (b, h, l, d))
        lens = jnp.full((b,), l, jnp.int32)
        outs = [async_softmax_attention(q, k, v, lens, phi=p)[0]
                for p in (-2.0, 0.0, 3.0)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=2e-5, rtol=2e-5)

    def test_unified_ref_equals_stable_ref(self):
        """The unified-max oracle itself is exact for in-range phi."""
        q = rand(15, (2, 2, 64))
        k = rand(16, (2, 2, 128, 64))
        v = rand(17, (2, 2, 128, 64))
        a = ref.unified_softmax_attention_ref(q, k, v, phi=1.0)
        b_ = ref.attention_decode_ref(q, k, v)
        np.testing.assert_allclose(a, b_, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("block_l", [32, 64, 128, 256])
    def test_block_size_invariance(self, block_l):
        b, h, l, d = 2, 2, 256, 64
        q = rand(18, (b, h, d))
        k = rand(19, (b, h, l, d))
        v = rand(20, (b, h, l, d))
        lens = jnp.full((b,), 200, jnp.int32)
        o, _ = async_softmax_attention(q, k, v, lens, block_l=block_l)
        want = ref.attention_decode_ref(q, k, v, kv_len=200)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 4), h=st.sampled_from([1, 2, 4]),
        l=st.sampled_from([64, 128, 192, 256]),
        d=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**16),
        scale=st.sampled_from([0.1, 1.0, 5.0]),
    )
    def test_hypothesis_sweep(self, b, h, l, d, seed, scale):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        q = jax.random.normal(k1, (b, h, d)) * scale
        k = jax.random.normal(k2, (b, h, l, d))
        v = jax.random.normal(k3, (b, h, l, d))
        lens = jax.random.randint(k4, (b,), 1, l + 1).astype(jnp.int32)
        o, _ = async_softmax_attention(q, k, v, lens)
        for i in range(b):
            want = ref.attention_decode_ref(
                q[i:i+1], k[i:i+1], v[i:i+1], kv_len=int(lens[i]))
            np.testing.assert_allclose(o[i:i+1], want, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# Baseline: synchronized partial softmax
# ---------------------------------------------------------------------------

class TestSyncSoftmaxAttention:
    @pytest.mark.parametrize("b,h,l,d", [(1, 1, 128, 64), (2, 4, 256, 64)])
    def test_matches_oracle(self, b, h, l, d):
        q = rand(21, (b, h, d))
        k = rand(22, (b, h, l, d))
        v = rand(23, (b, h, l, d))
        o = sync_softmax_attention(q, k, v, jnp.full((b,), l, jnp.int32))
        want = ref.attention_decode_ref(q, k, v, kv_len=l)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)

    def test_extreme_values_safe(self):
        """The synchronized scheme must be exact even at huge logits —
        it is the fallback the async path relies on."""
        b, h, l, d = 1, 2, 128, 64
        q = rand(24, (b, h, d), scale=100.0)
        k = rand(25, (b, h, l, d))
        v = rand(26, (b, h, l, d))
        o = sync_softmax_attention(q, k, v, jnp.full((b,), l, jnp.int32))
        want = ref.attention_decode_ref(q, k, v, kv_len=l)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)

    def test_agrees_with_async(self):
        b, h, l, d = 2, 2, 256, 64
        q = rand(27, (b, h, d))
        k = rand(28, (b, h, l, d))
        v = rand(29, (b, h, l, d))
        lens = jnp.full((b,), 180, jnp.int32)
        o_sync = sync_softmax_attention(q, k, v, lens)
        o_async, _ = async_softmax_attention(q, k, v, lens)
        np.testing.assert_allclose(o_sync, o_async, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# C2 / ImplA / ImplC: GEMM kernels
# ---------------------------------------------------------------------------

GEMM_SHAPES = [
    (1, 256, 768),    # tiny-model qkv, M=1 (GEMV regime)
    (4, 256, 256),    # o_proj, small batch
    (8, 256, 1024),   # ffn1 at the paper's pad-to-8 boundary
    (3, 512, 512),    # M not a multiple of 8 -> padding correctness
    (8, 1000, 300),   # K, N not multiples of the block sizes
    (16, 256, 512),
]


class TestFlatGemm:
    @pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
    def test_matches_oracle(self, m, k, n):
        x = rand(30 + m, (m, k))
        w = rand(60 + n % 7, (k, n))
        np.testing.assert_allclose(
            flat_gemm(x, w), ref.matmul_ref(x, w), atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("block_n,block_k", [(64, 64), (128, 128),
                                                 (256, 64), (32, 256)])
    def test_tile_invariance(self, block_n, block_k):
        x = rand(40, (8, 512))
        w = rand(41, (512, 1024))
        got = flat_gemm(x, w, block_n=block_n, block_k=block_k)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w),
                                   atol=1e-4, rtol=1e-4)

    def test_m_padding_zero_rows_dont_leak(self):
        """Padded rows must not influence the real rows."""
        x = rand(42, (2, 256))
        w = rand(43, (256, 512))
        got2 = flat_gemm(x, w)
        got8 = flat_gemm(jnp.pad(x, ((0, 6), (0, 0))), w)[:2]
        np.testing.assert_allclose(got2, got8, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 16), k=st.sampled_from([128, 256, 384, 1000]),
           n=st.sampled_from([128, 300, 512, 1024]), seed=st.integers(0, 999))
    def test_hypothesis_sweep(self, m, k, n, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (m, k))
        w = jax.random.normal(k2, (k, n))
        np.testing.assert_allclose(
            flat_gemm(x, w), ref.matmul_ref(x, w), atol=2e-4, rtol=2e-4)


class TestConventionalGemm:
    @pytest.mark.parametrize("m,k,n", [(64, 256, 512), (100, 300, 200),
                                       (128, 256, 768), (7, 256, 256)])
    def test_matches_oracle(self, m, k, n):
        x = rand(50, (m, k))
        w = rand(51, (k, n))
        np.testing.assert_allclose(
            conventional_gemm(x, w), ref.matmul_ref(x, w),
            atol=2e-4, rtol=2e-4)


class TestGemv:
    @pytest.mark.parametrize("m,k,n", [(1, 256, 768), (1, 1024, 512),
                                       (2, 256, 256), (4, 300, 1000)])
    def test_matches_oracle(self, m, k, n):
        x = rand(52, (m, k))
        w = rand(53, (k, n))
        np.testing.assert_allclose(
            gemv(x, w), ref.matmul_ref(x, w), atol=1e-4, rtol=1e-4)

    def test_all_impls_agree(self):
        x = rand(54, (4, 512))
        w = rand(55, (512, 768))
        a = gemv(x, w)
        b = flat_gemm(x, w)
        c = conventional_gemm(x, w)
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(b, c, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# ref.py self-consistency
# ---------------------------------------------------------------------------

class TestRef:
    def test_softmax_ref_sums_to_one(self):
        x = rand(56, (4, 100))
        p = ref.softmax_ref(x)
        np.testing.assert_allclose(p.sum(-1), np.ones(4), atol=1e-6)

    def test_softmax_ref_invariant_to_shift(self):
        x = rand(57, (2, 64))
        np.testing.assert_allclose(ref.softmax_ref(x),
                                   ref.softmax_ref(x + 5.0), atol=1e-6)

    def test_prefill_ref_is_causal(self):
        """Future tokens must not affect earlier outputs."""
        b, h, s, d = 1, 2, 16, 32
        q = rand(58, (b, h, s, d))
        k = rand(59, (b, h, s, d))
        v = rand(60, (b, h, s, d))
        o_full = ref.attention_prefill_ref(q, k, v)
        o_half = ref.attention_prefill_ref(
            q[:, :, :8], k[:, :, :8], v[:, :, :8])
        np.testing.assert_allclose(o_full[:, :, :8], o_half,
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# C1 prefill: unified-max causal attention
# ---------------------------------------------------------------------------

from compile.kernels.async_softmax_prefill import async_softmax_prefill  # noqa: E402


class TestAsyncSoftmaxPrefill:
    @pytest.mark.parametrize("b,h,s,d", [
        (1, 1, 32, 32), (2, 2, 64, 32), (1, 4, 128, 64), (2, 1, 16, 64),
    ])
    def test_matches_oracle(self, b, h, s, d):
        q = rand(70, (b, h, s, d))
        k = rand(71, (b, h, s, d))
        v = rand(72, (b, h, s, d))
        o, flags = async_softmax_prefill(q, k, v)
        want = ref.attention_prefill_ref(q, k, v)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)
        assert float(flags.sum()) == 0.0

    @pytest.mark.parametrize("block_q,block_kv", [(8, 8), (16, 64), (64, 16)])
    def test_block_invariance(self, block_q, block_kv):
        q = rand(73, (1, 2, 64, 32))
        k = rand(74, (1, 2, 64, 32))
        v = rand(75, (1, 2, 64, 32))
        o, _ = async_softmax_prefill(q, k, v, block_q=block_q,
                                     block_kv=block_kv)
        want = ref.attention_prefill_ref(q, k, v)
        np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)

    def test_overflow_fallback_exact(self):
        q = rand(76, (1, 2, 64, 32), scale=50.0)
        k = rand(77, (1, 2, 64, 32))
        v = rand(78, (1, 2, 64, 32))
        o, flags = async_softmax_prefill(q, k, v, phi=0.0, b=15.0)
        want = ref.attention_prefill_ref(q, k, v)
        np.testing.assert_allclose(o, want, atol=3e-5, rtol=3e-5)
        assert float(flags.sum()) > 0

    def test_causality(self):
        """Perturbing future K/V must not change earlier outputs."""
        q = rand(79, (1, 1, 64, 32))
        k = rand(80, (1, 1, 64, 32))
        v = rand(81, (1, 1, 64, 32))
        o1, _ = async_softmax_prefill(q, k, v)
        k2 = k.at[:, :, 32:, :].add(5.0)
        v2 = v.at[:, :, 32:, :].add(-3.0)
        o2, _ = async_softmax_prefill(q, k2, v2)
        np.testing.assert_allclose(o1[:, :, :32], o2[:, :, :32],
                                   atol=1e-6, rtol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(s=st.sampled_from([16, 32, 48, 64]), d=st.sampled_from([32, 64]),
           seed=st.integers(0, 999))
    def test_hypothesis_sweep(self, s, d, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (1, 2, s, d))
        k = jax.random.normal(k2, (1, 2, s, d))
        v = jax.random.normal(k3, (1, 2, s, d))
        o, _ = async_softmax_prefill(q, k, v)
        want = ref.attention_prefill_ref(q, k, v)
        np.testing.assert_allclose(o, want, atol=5e-5, rtol=5e-5)
