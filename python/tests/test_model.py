# L2 correctness: the transformer entry points.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab_size=512, dim=128, n_layers=2, n_heads=2,
                    ffn_hidden=256)
WS = M.init_weights(CFG, seed=0)
LMAX = 64


def make_cache(b):
    shape = (CFG.n_layers, b, CFG.n_heads, LMAX, CFG.head_dim)
    return jnp.zeros(shape), jnp.zeros(shape)


def prime_cache_from_prefill(kc, vc, k, v, lane, length):
    """Insert prefill KV [Lyr,1,H,S,Dh] into decode cache lane."""
    kc = kc.at[:, lane, :, :length, :].set(k[:, 0, :, :length, :])
    vc = vc.at[:, lane, :, :length, :].set(v[:, 0, :, :length, :])
    return kc, vc


class TestPrefillDecodeConsistency:
    def test_decode_continues_prefill(self):
        """Prefill n tokens, then decode token n; logits must equal a
        prefill over n+1 tokens at the last position."""
        toks = jax.random.randint(jax.random.PRNGKey(0), (1, 9), 0,
                                  CFG.vocab_size)
        full_logits, _, _ = M.prefill(CFG, WS, toks)
        # prefill first 8, then decode token 8
        lg8, k8, v8 = M.prefill(CFG, WS, toks[:, :8])
        kc, vc = make_cache(1)
        kc, vc = prime_cache_from_prefill(kc, vc, k8, v8, 0, 8)
        logits, _, _, flags = M.decode_step(
            CFG, WS, toks[0, 8:9], jnp.array([8], jnp.int32), kc, vc,
            impl="flat", attn="async")
        np.testing.assert_allclose(
            logits[0], full_logits[8], atol=2e-4, rtol=2e-4)

    def test_multi_step_decode_matches_prefill(self):
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                  CFG.vocab_size)
        full_logits, _, _ = M.prefill(CFG, WS, toks)
        lg, k, v = M.prefill(CFG, WS, toks[:, :8])
        kc, vc = make_cache(1)
        kc, vc = prime_cache_from_prefill(kc, vc, k, v, 0, 8)
        for t in range(8, 12):
            logits, kc, vc, _ = M.decode_step(
                CFG, WS, toks[0, t:t+1], jnp.array([t], jnp.int32), kc, vc)
            np.testing.assert_allclose(
                logits[0], full_logits[t], atol=5e-4, rtol=5e-4,
                err_msg=f"step {t}")

    @pytest.mark.parametrize("impl", ["gemv", "flat", "conv", "jnp"])
    def test_impl_variants_agree(self, impl):
        """C3: every GEMM implementation must produce the same logits."""
        toks = jnp.array([3], jnp.int32)
        kc, vc = make_cache(1)
        ref_logits, _, _, _ = M.decode_step(
            CFG, WS, toks, jnp.array([0], jnp.int32), kc, vc, impl="jnp",
            attn="jnp")
        logits, _, _, _ = M.decode_step(
            CFG, WS, toks, jnp.array([0], jnp.int32), kc, vc, impl=impl,
            attn="async")
        np.testing.assert_allclose(logits, ref_logits, atol=2e-4, rtol=2e-4)

    def test_sync_and_async_attention_agree(self):
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0,
                                  CFG.vocab_size)
        _, k, v = M.prefill(CFG, WS, toks)
        kc, vc = make_cache(2)
        kc, vc = prime_cache_from_prefill(kc, vc, k, v, 0, 10)
        args = (CFG, WS, jnp.array([7, 0], jnp.int32),
                jnp.array([10, 0], jnp.int32), kc, vc)
        la, _, _, _ = M.decode_step(*args, attn="async")
        ls, _, _, _ = M.decode_step(*args, attn="sync")
        np.testing.assert_allclose(la, ls, atol=2e-4, rtol=2e-4)

    def test_batched_decode_lanes_independent(self):
        """A lane's logits must not depend on other lanes' content."""
        toks = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0,
                                  CFG.vocab_size)
        _, k, v = M.prefill(CFG, WS, toks)
        # lane 0 alone
        kc1, vc1 = make_cache(1)
        kc1, vc1 = prime_cache_from_prefill(kc1, vc1, k, v, 0, 6)
        solo, _, _, _ = M.decode_step(
            CFG, WS, jnp.array([5], jnp.int32), jnp.array([6], jnp.int32),
            kc1, vc1)
        # lane 0 with a noisy lane 1
        kc2, vc2 = make_cache(2)
        kc2, vc2 = prime_cache_from_prefill(kc2, vc2, k, v, 0, 6)
        kc2 = kc2.at[:, 1].set(
            jax.random.normal(jax.random.PRNGKey(4), kc2[:, 1].shape))
        duo, _, _, _ = M.decode_step(
            CFG, WS, jnp.array([5, 9], jnp.int32),
            jnp.array([6, 3], jnp.int32), kc2, vc2)
        np.testing.assert_allclose(duo[0], solo[0], atol=1e-4, rtol=1e-4)


class TestCacheWrite:
    def test_decode_writes_kv_at_position(self):
        kc, vc = make_cache(1)
        _, kc2, vc2, _ = M.decode_step(
            CFG, WS, jnp.array([42], jnp.int32), jnp.array([5], jnp.int32),
            kc, vc)
        # position 5 must now be non-zero, all others untouched (zero)
        assert float(jnp.abs(kc2[:, 0, :, 5, :]).sum()) > 0
        untouched = jnp.concatenate(
            [kc2[:, 0, :, :5, :], kc2[:, 0, :, 6:, :]], axis=2)
        assert float(jnp.abs(untouched).sum()) == 0.0

    def test_per_lane_positions(self):
        kc, vc = make_cache(2)
        _, kc2, _, _ = M.decode_step(
            CFG, WS, jnp.array([1, 2], jnp.int32),
            jnp.array([3, 7], jnp.int32), kc, vc)
        assert float(jnp.abs(kc2[:, 0, :, 3, :]).sum()) > 0
        assert float(jnp.abs(kc2[:, 1, :, 7, :]).sum()) > 0
        assert float(jnp.abs(kc2[:, 0, :, 7, :]).sum()) == 0.0


class TestScores:
    def test_prefill_scores_shape_and_causality_irrelevant(self):
        toks = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0,
                                  CFG.vocab_size)
        _, _, _, scores = M.prefill(CFG, WS, toks, return_scores=True)
        assert scores.shape == (CFG.n_layers, CFG.n_heads, 8, 8)
        assert bool(jnp.all(jnp.isfinite(scores)))

    def test_rope_positions_matter(self):
        """Same token at different positions must produce different KV."""
        kc, vc = make_cache(1)
        _, ka, _, _ = M.decode_step(
            CFG, WS, jnp.array([7], jnp.int32), jnp.array([0], jnp.int32),
            kc, vc)
        _, kb, _, _ = M.decode_step(
            CFG, WS, jnp.array([7], jnp.int32), jnp.array([9], jnp.int32),
            kc, vc)
        a = ka[:, 0, :, 0, :]
        b = kb[:, 0, :, 9, :]
        assert float(jnp.abs(a - b).max()) > 1e-4


class TestWeights:
    def test_weight_shapes_match_spec(self):
        shapes = M.weight_shapes(CFG)
        for name, arr in WS.items():
            assert tuple(arr.shape) == shapes[name], name

    def test_weights_deterministic(self):
        w2 = M.init_weights(CFG, seed=0)
        for name in M.WEIGHT_ORDER:
            np.testing.assert_array_equal(WS[name], w2[name])

    def test_weights_list_order(self):
        lst = M.weights_list(WS)
        assert len(lst) == len(M.WEIGHT_ORDER)
        back = M.weights_dict(lst)
        for name in M.WEIGHT_ORDER:
            np.testing.assert_array_equal(back[name], WS[name])
