# AOT pipeline: manifest consistency and HLO-text well-formedness.
# These run against the generated artifacts/ when present (CI runs
# `make artifacts` first); otherwise they validate the generator logic.
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))


def test_to_hlo_text_roundtrippable():
    """The HLO text must parse as an HloModule header (the format the
    rust side's HloModuleProto::from_text_file consumes)."""
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_choose_phi_centers_distribution():
    cfg = M.ModelConfig(vocab_size=512, dim=64, n_layers=1, n_heads=2,
                        ffn_hidden=128)
    ws = M.init_weights(cfg)
    phi, stats = aot.choose_phi(cfg, ws, seq=16, n_prompts=2)
    assert stats["min"] <= phi <= stats["max"]
    assert stats["count"] > 0
    # the window must cover the observed extremes (paper §3 requirement)
    assert stats["max"] - phi < cfg.softmax_b
    assert stats["min"] - phi > cfg.softmax_a


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="run `make artifacts` first")
class TestManifest:
    @classmethod
    def setup_class(cls):
        with open(os.path.join(ART, "manifest.json")) as f:
            cls.man = json.load(f)

    def test_model_block_complete(self):
        m = self.man["model"]
        for key in ("name", "vocab_size", "dim", "n_layers", "n_heads",
                    "head_dim", "ffn_hidden", "max_seq", "phi",
                    "softmax_a", "softmax_b"):
            assert key in m, key
        assert m["dim"] == m["n_heads"] * m["head_dim"]

    def test_all_entry_files_exist(self):
        for e in self.man["entries"]:
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), e["name"]
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), e["name"]

    def test_weight_files_match_shapes(self):
        for w in self.man["weights"]:
            arr = np.load(os.path.join(ART, w["file"]))
            assert list(arr.shape) == w["shape"], w["name"]
            assert str(arr.dtype) == w["dtype"], w["name"]

    def test_decode_buckets_present(self):
        names = {e["name"] for e in self.man["entries"]}
        for b in aot.DECODE_BATCHES:
            assert f"decode_b{b}" in names
        for b in aot.SYNC_BATCHES:
            assert f"decode_b{b}_sync" in names
            assert f"decode_b{b}_jnpattn" in names
        for s in aot.PREFILL_SEQS:
            assert f"prefill_s{s}" in names
        assert f"prefill_scores_s{aot.SCORES_SEQ}" in names

    def test_entry_input_counts(self):
        n_w = len(self.man["weight_order"])
        for e in self.man["entries"]:
            if e["kind"] == "decode":
                assert len(e["inputs"]) == n_w + 4, e["name"]
                assert e["num_outputs"] == 4
            elif e["kind"] in ("prefill", "scores"):
                assert len(e["inputs"]) == n_w + 1, e["name"]
            elif e["kind"] == "micro":
                assert len(e["inputs"]) == 2
                assert not e["takes_weights"]

    def test_decode_cache_shapes_consistent(self):
        m = self.man["model"]
        for e in self.man["entries"]:
            if e["kind"] != "decode":
                continue
            b = e["params"]["batch"]
            cache = e["inputs"][-1]["shape"]
            assert cache == [m["n_layers"], b, m["n_heads"], m["max_seq"],
                             m["head_dim"]], e["name"]

    def test_linear_shapes_block(self):
        ls = self.man["linear_shapes"]
        assert set(ls) == {"qkv_proj", "o_proj", "ffn1", "ffn2"}
        m = self.man["model"]
        assert ls["qkv_proj"] == [3 * m["dim"], m["dim"]]

    def test_softmax_stats_recorded(self):
        s = self.man["softmax_input_stats"]
        assert s["min"] < s["max"]
        assert s["count"] > 1000
        # phi within the observed range (paper §3 insight: x_i is
        # concentrated in a narrow static range)
        assert s["min"] <= self.man["model"]["phi"] <= s["max"]
