//! C3 walkthrough: run the §5 decision flow offline (Figure 9(b)) on the
//! real CPU microkernel artifacts, print the inflection points, persist
//! the lookup table, and demonstrate runtime dispatch (Figure 9(c)).
//!
//!     cargo run --release --example heuristic_profile [reps]

use fdpp::dataflow::profile::build_lookup_table;
use fdpp::dataflow::ImplKind;
use fdpp::runtime::Runtime;

fn main() -> fdpp::Result<()> {
    let reps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let mut rt = Runtime::load("artifacts")?;
    println!("profiling micro GEMM artifacts (reps={reps}) on {}", rt.platform());
    let table = build_lookup_table(&mut rt, reps)?;

    println!("\nlookup table ({} / {}):", table.model, table.hardware);
    println!("{:<22} {:>8} {:>8}", "op [N,K]", "M1", "M2");
    for e in &table.entries {
        println!(
            "{:<22} {:>8} {:>8}",
            format!("{} [{},{}]", e.op, e.n, e.k),
            e.m1,
            e.m2
        );
    }

    println!("\nruntime dispatch demo (Figure 9(c)):");
    for m in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let picks: Vec<String> = table
            .entries
            .iter()
            .map(|e| {
                let ik = e.dispatch(m);
                let tag = match ik {
                    ImplKind::A => "A",
                    ImplKind::B => "B",
                    ImplKind::C => "C",
                };
                format!("{}:{}", e.op, tag)
            })
            .collect();
        println!("  M={m:<4} -> {}", picks.join("  "));
    }

    table.save_json("artifacts/lookup_table.json")?;
    println!("\nwrote artifacts/lookup_table.json");
    Ok(())
}
