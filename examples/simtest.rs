//! Seeded simulation-test runner — the CLI side of `fdpp::simtest`.
//!
//! Usage:
//!   cargo run --example simtest                      # fixed matrix 1..=24
//!   cargo run --example simtest -- --seed 7          # replay one seed
//!   cargo run --example simtest -- --seeds 1..100    # a seed range
//!   cargo run --example simtest -- --random-seeds 25 # smoke mode
//!   cargo run --example simtest -- --fleet 3         # N-replica fleet
//!   cargo run --example simtest -- --fleet 3 --kill  # + replica death
//!   cargo run --example simtest -- --shards 2        # sharded backend
//!
//! `--fleet N` runs every selected seed through an N-replica
//! [`fdpp::fleet::Fleet`] under the same five oracles; `--kill`
//! additionally kills a seed-chosen replica mid-run and checks that
//! its in-flight work restarts on the survivors with nothing lost or
//! duplicated. `--shards M` swaps every engine's backend for
//! [`fdpp::shard::ShardedBackend`] with M simulated tensor-parallel
//! lanes (composable with `--fleet`/`--kill`) — the reports, sharded
//! or not, must be byte-identical, so a divergence is a sharding bug.
//! Any oracle violation prints the offending seed plus a replay
//! command and exits nonzero — CI echoes exactly what to run locally.

use fdpp::simtest::{
    run_replica_kill, run_replica_kill_sharded, run_scenario, run_scenario_fleet,
    run_scenario_fleet_sharded, run_scenario_sharded,
};

fn entropy_seed() -> u64 {
    // Smoke mode only: fixed runs never call this.
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0x5EED);
    fdpp::util::rng::splitmix64(nanos ^ (std::process::id() as u64).rotate_left(32))
}

fn usage() -> ! {
    eprintln!(
        "usage: simtest [--seed N]... [--seeds LO..HI] [--random-seeds N] \
         [--fleet N] [--kill] [--shards M]\n\
         (no arguments: the fixed seed matrix 1..=24; --kill needs --fleet >= 2)"
    );
    std::process::exit(2)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut seeds: Vec<u64> = Vec::new();
    let mut fleet: Option<usize> = None;
    let mut shards: Option<usize> = None;
    let mut kill = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--seed" => {
                i += 1;
                let s = args.get(i).unwrap_or_else(|| usage());
                seeds.push(s.parse().unwrap_or_else(|_| usage()));
            }
            "--seeds" => {
                i += 1;
                let s = args.get(i).unwrap_or_else(|| usage());
                let (lo, hi) = s.split_once("..").unwrap_or_else(|| usage());
                let lo: u64 = lo.parse().unwrap_or_else(|_| usage());
                let hi: u64 = hi.parse().unwrap_or_else(|_| usage());
                if lo >= hi {
                    // An empty range must not silently fall back to the
                    // default matrix and report success.
                    eprintln!("--seeds {lo}..{hi} is empty (hi is exclusive)");
                    std::process::exit(2);
                }
                seeds.extend(lo..hi);
            }
            "--random-seeds" => {
                i += 1;
                let s = args.get(i).unwrap_or_else(|| usage());
                let n: u64 = s.parse().unwrap_or_else(|_| usage());
                let mut x = entropy_seed();
                for _ in 0..n {
                    seeds.push(x);
                    x = x.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
                }
            }
            "--fleet" => {
                i += 1;
                let s = args.get(i).unwrap_or_else(|| usage());
                let n: usize = s.parse().unwrap_or_else(|_| usage());
                if n == 0 {
                    usage();
                }
                fleet = Some(n);
            }
            "--kill" => kill = true,
            "--shards" => {
                i += 1;
                let s = args.get(i).unwrap_or_else(|| usage());
                let m: usize = s.parse().unwrap_or_else(|_| usage());
                if m == 0 {
                    usage();
                }
                shards = Some(m);
            }
            _ => usage(),
        }
        i += 1;
    }
    if seeds.is_empty() {
        seeds.extend(1..=24);
    }
    if kill && fleet.map(|n| n < 2).unwrap_or(true) {
        eprintln!("--kill needs --fleet with at least 2 replicas");
        std::process::exit(2);
    }

    let mut failed = false;
    for &seed in &seeds {
        let result = match (fleet, kill, shards) {
            (Some(n), true, Some(m)) => run_replica_kill_sharded(seed, n, m),
            (Some(n), true, None) => run_replica_kill(seed, n),
            (Some(n), false, Some(m)) => run_scenario_fleet_sharded(seed, n, m),
            (Some(n), false, None) => run_scenario_fleet(seed, n),
            (None, _, Some(m)) => run_scenario_sharded(seed, m),
            (None, _, None) => run_scenario(seed),
        };
        match result {
            Ok(r) => println!(
                "seed {seed:>20}: ok  ({} steps, {} reqs, {} finished, {} tok, \
                 {} preempt, {} pause/{} resume, {} expired, fp {:016x})",
                r.steps,
                r.requests,
                r.finished,
                r.tokens_generated,
                r.preemptions,
                r.pauses,
                r.resumes,
                r.expired,
                r.fingerprint
            ),
            Err(v) => {
                eprintln!("{v}");
                let mut replay = format!("cargo run --example simtest -- --seed {seed}");
                if let Some(n) = fleet {
                    replay.push_str(&format!(" --fleet {n}"));
                }
                if kill {
                    replay.push_str(" --kill");
                }
                if let Some(m) = shards {
                    replay.push_str(&format!(" --shards {m}"));
                }
                eprintln!("replay: {replay}");
                eprintln!("SIMTEST FAILING SEED: {seed}");
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
    let mode = match (fleet, kill) {
        (Some(n), true) => format!(" (fleet of {n}, replica kill)"),
        (Some(n), false) => format!(" (fleet of {n})"),
        (None, _) => String::new(),
    };
    let lanes = shards
        .map(|m| format!(" ({m} lanes/backend)"))
        .unwrap_or_default();
    println!("{} scenario(s) passed all oracles{mode}{lanes}", seeds.len());
}
