//! Quickstart: load the AOT artifacts, generate text, print metrics.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! The tiny model's weights are synthetic, so the *text* is noise — the
//! point is the full path: byte tokenizer -> bucketed prefill ->
//! continuous-batched decode on the asynchronized-softmax kernels ->
//! sampling -> streaming, all from Rust with Python long gone.

use fdpp::api::InferenceEngine;
use fdpp::config::EngineConfig;
use fdpp::engine::Engine;
use fdpp::runtime::Runtime;
use fdpp::sampling::SamplingParams;

fn main() -> fdpp::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    println!("loading artifacts from {artifacts}/ ...");
    let rt = Runtime::load(&artifacts)?;
    println!(
        "model={} ({} layers, dim {}, vocab {}), platform={}",
        rt.manifest.model.name,
        rt.manifest.model.n_layers,
        rt.manifest.model.dim,
        rt.manifest.model.vocab_size,
        rt.platform()
    );

    let mut engine = Engine::new(rt, EngineConfig::default())?;
    print!("warmup (compiling decode/prefill buckets)... ");
    let t0 = std::time::Instant::now();
    engine.warmup()?;
    println!("done in {:.1?}", t0.elapsed());

    for prompt in ["What is the largest ocean?", "flash decoding"] {
        let t0 = std::time::Instant::now();
        let out = engine.generate_text(prompt, 24, SamplingParams::default())?;
        println!(
            "prompt {prompt:?} -> {} bytes generated in {:.2?}",
            out.len(),
            t0.elapsed()
        );
    }

    let m = &engine.metrics;
    println!("\n-- engine metrics --");
    println!("prefill steps        {}", m.prefill_steps);
    println!("decode steps         {}", m.decode_steps);
    println!("tokens generated     {}", m.tokens_generated);
    println!("mean step            {:?}", m.step.mean());
    println!("mean step overhead   {:?} (host-side, non-PJRT)", m.step_overhead.mean());
    println!("recompute rate       {:.4} (C1 fallback, paper §3)", m.recompute_rate());
    println!("kv rebuilds          {}", m.kv_rebuilds);
    Ok(())
}
