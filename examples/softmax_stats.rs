//! C1 walkthrough (Figure 5): measure the softmax-input distribution of
//! the *real* tiny model by executing the `prefill_scores` artifact, then
//! derive the unified-max policy (phi + enable/disable) the way the
//! engine does offline for each model.
//!
//!     cargo run --release --example softmax_stats

use fdpp::runtime::{literal_i32, to_vec_f32, Runtime};
use fdpp::softmaxstats::{derive_policy, paper_figure5_ranges, SoftmaxInputStats};
use fdpp::util::rng::Rng;

fn main() -> fdpp::Result<()> {
    let mut rt = Runtime::load("artifacts")?;
    let vocab = rt.manifest.model.vocab_size;
    let seq = 64usize;
    let mut rng = Rng::seed_from_u64(7);
    let mut stats = SoftmaxInputStats::new();

    println!("running prefill_scores_s{seq} over 4 synthetic prompts ...");
    for _ in 0..4 {
        let toks: Vec<i32> = (0..seq).map(|_| rng.gen_range(0, vocab - 1) as i32).collect();
        let toks = literal_i32(&toks, &[1, seq])?;
        let outs = rt.execute(&format!("prefill_scores_s{seq}"), &[&toks])?;
        // outputs: logits, k, v, scores [Lyr, H, S, S]
        let scores = to_vec_f32(&outs[3])?;
        // keep causal-valid entries only
        let (lyr, heads) = (rt.manifest.model.n_layers, rt.manifest.model.n_heads);
        for l in 0..lyr {
            for h in 0..heads {
                for i in 0..seq {
                    for j in 0..=i {
                        let idx = ((l * heads + h) * seq + i) * seq + j;
                        stats.push(scores[idx] as f64);
                    }
                }
            }
        }
    }

    println!("\nmeasured on the real tiny model (x_i = QK^T/sqrt(d)):");
    println!(
        "  count={} min={:.2} max={:.2} mean={:.3} std={:.3}",
        stats.count, stats.min, stats.max, stats.mean,
        stats.std()
    );
    let policy = derive_policy(&stats);
    println!(
        "  -> policy: enabled={} phi={:.3} window=({}, {}) expected recompute {:.2e}",
        policy.enabled, policy.phi, policy.a, policy.b, policy.expected_recompute_rate
    );
    println!(
        "  manifest phi (chosen at AOT time): {:.3}",
        rt.manifest.model.phi
    );

    println!("\npaper Figure 5 ranges -> per-model decisions:");
    for (name, lo, hi) in paper_figure5_ranges() {
        let mut s = SoftmaxInputStats::new();
        for i in 0..512 {
            s.push(lo + (hi - lo) * i as f64 / 511.0);
        }
        let p = derive_policy(&s);
        println!(
            "  {:<14} range [{:>6.1}, {:>5.1}] -> async softmax {}",
            name,
            lo,
            hi,
            if p.enabled { "ENABLED" } else { "disabled (recompute-prone)" }
        );
    }
    Ok(())
}
