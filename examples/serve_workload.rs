//! END-TO-END driver (EXPERIMENTS.md §E2E), three acts:
//!
//! 1. **Flow-control demo** (sim engine, runs on a bare checkout):
//!    mixed-priority traffic with one deliberately slow consumer,
//!    under both backpressure policies, printing the new
//!    backpressure / preemption / per-priority metrics.
//! 2. **Fleet demo** (sim engine): three replicas behind the
//!    cache-aware router serving a Zipf shared-prefix workload, one
//!    replica drained mid-run; prints per-replica routing decisions
//!    and prefix-cache hits.
//! 3. **PJRT workload** (needs `make artifacts`): serve a
//!    Poisson-arrival workload of batched requests on the real tiny
//!    model and report latency/throughput, comparing the
//!    asynchronized-softmax engine (C1 on) against the synchronized
//!    baseline (C1 off) on the same trace. Skipped with a note when
//!    artifacts are unavailable.
//!
//! Both acts end with a perf report: the request-phase breakdown
//! (queue wait / prefill / decode / paused, aggregated by the span
//! histograms) and the TTFT/step latency percentiles — the same
//! numbers `docs/OBSERVABILITY.md` documents on the stats surface.
//!
//!     cargo run --release --example serve_workload [n_requests] [rate]

use std::time::{Duration, Instant};

use fdpp::api::{GenEvent, GenRequest, InferenceEngine, SubmissionHandle};
use fdpp::config::{BackpressurePolicy, EngineConfig, FleetConfig, RoutePolicy};
use fdpp::engine::Engine;
use fdpp::fleet::Fleet;
use fdpp::runtime::Runtime;
use fdpp::simengine::{SimEngine, SimSpec};
use fdpp::workload::{generate, shared_prefix_trace, SharedPrefixSpec, WorkloadSpec};

struct RunReport {
    label: String,
    wall: Duration,
    tokens: u64,
    finished: u64,
    p50_first: Duration,
    p95_first: Duration,
    p50_token: Duration,
    p95_token: Duration,
    recompute_rate: f64,
    kv_rebuilds: u64,
    mean_overhead: Duration,
    perf: String,
}

/// End-of-run perf report: the request-phase breakdown aggregated by
/// the engine's span histograms, plus TTFT and step-time percentiles.
fn perf_lines(m: &fdpp::metrics::EngineMetrics) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "  -- end-of-run perf report --");
    for (name, h) in [
        ("queue wait", &m.span_queue_wait),
        ("prefill", &m.span_prefill),
        ("decode", &m.span_decode),
        ("paused", &m.span_paused),
    ] {
        let _ = writeln!(
            out,
            "  phase {name:<11} mean {:.2?}  p50 {:.2?}  p99 {:.2?}",
            h.mean(),
            h.percentile(0.5),
            h.percentile(0.99)
        );
    }
    let _ = writeln!(
        out,
        "  ttft             p50 {:.2?}  p90 {:.2?}  p99 {:.2?}",
        m.first_token.percentile(0.5),
        m.first_token.percentile(0.9),
        m.first_token.percentile(0.99)
    );
    let _ = write!(
        out,
        "  step             p50 {:.2?}  p99 {:.2?}  overhead mean {:.2?}",
        m.step.percentile(0.5),
        m.step.percentile(0.99),
        m.step_overhead.mean()
    );
    out
}

fn run(label: &str, async_softmax: bool, n: usize, rate: f64) -> fdpp::Result<RunReport> {
    let spec = WorkloadSpec {
        rate,
        n_requests: n,
        prompt_len: (8, 48),
        max_new_tokens: (8, 32),
        seed: 42,
    };
    let trace = generate(&spec);
    let cfg = EngineConfig {
        // The sync baseline artifacts exist for buckets {1, 8}.
        decode_buckets: if async_softmax {
            vec![1, 2, 4, 8]
        } else {
            vec![1, 8]
        },
        async_softmax,
        ..EngineConfig::default()
    };
    let rt = Runtime::load("artifacts")?;
    let mut engine = Engine::new(rt, cfg)?;
    engine.warmup()?;

    let start = Instant::now();
    let mut pending = trace.iter().peekable();
    let mut receivers = Vec::new();
    // Replay the trace in virtual time: submit when arrival <= now, step
    // the engine in between (open-loop load generation).
    while pending.peek().is_some() || !engine.is_idle() {
        let now = start.elapsed().as_secs_f64();
        while let Some(req) = pending.peek() {
            if req.arrival_s <= now {
                let req = pending.next().unwrap();
                let gen = GenRequest::text(req.prompt.as_str())
                    .tenant(req.tenant.as_str())
                    .max_new_tokens(req.max_new_tokens);
                receivers.push(engine.submit(gen)?);
            } else {
                break;
            }
        }
        if !engine.is_idle() {
            engine.step()?;
        } else if pending.peek().is_some() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let wall = start.elapsed();

    // Drain streams (all finished).
    let mut total_events = 0u64;
    for h in &receivers {
        while let Ok(ev) = h.events.try_recv() {
            if matches!(ev, GenEvent::Token(_)) {
                total_events += 1;
            }
        }
    }
    assert_eq!(total_events, engine.metrics.tokens_generated);

    let m = &engine.metrics;
    Ok(RunReport {
        label: label.to_string(),
        wall,
        tokens: m.tokens_generated,
        finished: m.requests_finished,
        p50_first: m.first_token.percentile(0.5),
        p95_first: m.first_token.percentile(0.95),
        p50_token: m.per_token.percentile(0.5),
        p95_token: m.per_token.percentile(0.95),
        recompute_rate: m.recompute_rate(),
        kv_rebuilds: m.kv_rebuilds,
        mean_overhead: m.step_overhead.mean(),
        perf: perf_lines(m),
    })
}

fn print_report(r: &RunReport) {
    println!("\n== {} ==", r.label);
    println!("requests finished     {}", r.finished);
    println!("tokens generated      {}", r.tokens);
    println!("wall time             {:.2?}", r.wall);
    println!(
        "throughput            {:.1} tok/s",
        r.tokens as f64 / r.wall.as_secs_f64()
    );
    println!("first-token p50/p95   {:.2?} / {:.2?}", r.p50_first, r.p95_first);
    println!("per-token  p50/p95    {:.2?} / {:.2?}", r.p50_token, r.p95_token);
    println!("recompute rate        {:.4}", r.recompute_rate);
    println!("kv rebuilds           {}", r.kv_rebuilds);
    println!("mean host overhead    {:.2?} per step", r.mean_overhead);
    println!("{}", r.perf);
}

/// Flow-control demo on the sim twin: mixed-priority traffic with one
/// deliberately slow consumer (drains one event every `SLOW_EVERY`
/// engine steps), small per-request stream buffers so backpressure
/// actually engages, and a tiny KV pool so preemption is
/// priority-ordered under pressure.
fn flow_control_demo(policy: BackpressurePolicy) -> fdpp::Result<()> {
    const SLOW_EVERY: usize = 24;
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        // Slightly under the workload's aggregate demand, so preemption
        // engages and is visibly priority-ordered.
        kv_total_blocks: 48,
        max_new_tokens: 48,
        stream_capacity: 4,
        backpressure: policy,
        ..EngineConfig::default()
    };
    let mut engine = SimEngine::new(cfg, SimSpec::default())?;

    // One slow consumer (priority 0), plus a mix of high/low priority
    // fast consumers.
    let slow = engine.submit(
        GenRequest::text("slow consumer with a long generation budget")
            .priority(0)
            .max_new_tokens(48),
    )?;
    let mut fast: Vec<(i32, SubmissionHandle)> = Vec::new();
    for i in 0..6 {
        let priority = if i % 2 == 0 { 5 } else { -1 };
        let h = engine.submit(
            GenRequest::text(format!("fast consumer {i} at priority {priority}"))
                .priority(priority)
                .max_new_tokens(16),
        )?;
        fast.push((priority, h));
    }
    println!(
        "  queue depths by priority at admission: {:?}",
        engine.queue_depths()
    );

    let mut slow_tokens = 0usize;
    let mut slow_fin = None;
    let mut steps = 0usize;
    let mut max_buffered = 0usize;
    while !engine.is_idle() && steps < 20_000 {
        engine.step()?;
        steps += 1;
        max_buffered = max_buffered.max(slow.events.buffered());
        // Fast consumers drain every step; the slow one only rarely.
        for (_, h) in &fast {
            while let Ok(_ev) = h.events.try_recv() {}
        }
        if steps % SLOW_EVERY == 0 {
            if let Ok(ev) = slow.events.try_recv() {
                match ev {
                    GenEvent::Token(_) => slow_tokens += 1,
                    GenEvent::Finished { reason, .. } => slow_fin = Some(reason),
                }
            }
        }
    }
    // Final drain of the slow stream.
    let (rest, fin) = slow.drain();
    slow_tokens += rest.len();
    if let Some((reason, _)) = fin {
        slow_fin = Some(reason);
    }

    let m = &engine.metrics;
    println!("  engine steps           {steps}");
    println!(
        "  slow stream            {} tokens delivered, finish {:?}, peak buffer {} (capacity 4)",
        slow_tokens, slow_fin, max_buffered
    );
    println!(
        "  backpressure           pauses {} / resumes {} / drops {}",
        m.backpressure_pauses, m.backpressure_resumes, m.backpressure_drops
    );
    println!(
        "  preemptions {} | finished {} | generated {} tokens",
        m.preemptions, m.requests_finished, m.tokens_generated
    );
    println!("{}", perf_lines(m));
    Ok(())
}

/// Fleet demo on the sim twin: three replicas behind the cache-aware
/// router, a Zipf shared-prefix workload (6 tenants, each repeating a
/// long system prompt), and one replica drained halfway through the
/// trace — it finishes its in-flight work, retires, and the router
/// re-concentrates its tenants on the survivors.
fn fleet_demo() -> fdpp::Result<()> {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 64,
        max_new_tokens: 16,
        max_running: 4,
        prefix_cache: true,
        ..EngineConfig::default()
    };
    let fcfg = FleetConfig {
        n_replicas: 3,
        policy: RoutePolicy::CacheAware,
        ..FleetConfig::default()
    };
    let mut fleet = Fleet::sim(cfg, fcfg, SimSpec::default())?;
    let spec = SharedPrefixSpec {
        n_tenants: 6,
        n_requests: 48,
        seed: 7,
        ..SharedPrefixSpec::default()
    };
    let trace = shared_prefix_trace(&spec);
    let drain_at = trace.len() / 2;
    let mut handles = Vec::new();
    for (i, r) in trace.iter().enumerate() {
        if i == drain_at {
            fleet.drain(2)?;
            println!("  draining replica 2 after {i} placements");
        }
        let gen = GenRequest::text(r.prompt.as_str())
            .tenant(r.tenant.as_str())
            .max_new_tokens(r.max_new_tokens);
        handles.push(fleet.submit(gen)?);
        // A little work between arrivals so the drain lands mid-run.
        for _ in 0..2 {
            if !fleet.is_idle() {
                fleet.step()?;
            }
        }
        for h in &handles {
            while h.events.try_recv().is_ok() {}
        }
    }
    let mut steps = 0usize;
    while !fleet.is_idle() && steps < 20_000 {
        fleet.step()?;
        steps += 1;
        for h in &handles {
            while h.events.try_recv().is_ok() {}
        }
    }

    let (decisions, cache_hits) = fleet.routing_counts();
    println!(
        "  routing                {} decisions, {} with a mirror-predicted prefix hit",
        decisions, cache_hits
    );
    for k in 0..fleet.n_replicas() {
        let s = fleet.replica_stats(k).expect("replica exists");
        println!(
            "  replica {k}              {:<8} routed {:>3}  prefix hits {:>3}/{:<3}  \
             finished {:>3}  tokens {:>4}",
            s.health.as_str(),
            s.routed,
            s.prefix_hits,
            s.prefix_lookups,
            s.requests_finished,
            s.tokens_generated
        );
    }
    let m = fleet.metrics();
    println!(
        "  fleet totals           finished {} | {} tokens | prefix hit rate {:.3}",
        m.requests_finished,
        m.tokens_generated,
        if m.prefix_lookups > 0 {
            m.prefix_hits as f64 / m.prefix_lookups as f64
        } else {
            0.0
        }
    );
    Ok(())
}

fn main() -> fdpp::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let rate: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);

    println!("== flow control demo (sim engine, artifact-free) ==");
    for policy in [BackpressurePolicy::PauseDecode, BackpressurePolicy::DropSlow] {
        println!("\npolicy {policy:?}:");
        flow_control_demo(policy)?;
    }

    println!("\n== fleet serving demo (3 sim replicas, cache-aware router) ==");
    fleet_demo()?;

    println!("\n== PJRT workload (requires make artifacts) ==");
    println!("serving {n} requests at ~{rate}/s on the tiny model (CPU PJRT)");
    let a = match run("FlashDecoding++ (asynchronized softmax, C1 on)", true, n, rate) {
        Ok(r) => r,
        Err(e) => {
            println!("skipping PJRT workload (artifacts unavailable): {e}");
            return Ok(());
        }
    };
    print_report(&a);
    let b = run("baseline (synchronized partial softmax, C1 off)", false, n, rate)?;
    print_report(&b);

    println!(
        "\nper-token p50 speedup from C1+buckets on this CPU testbed: {:.2}x",
        b.p50_token.as_secs_f64() / a.p50_token.as_secs_f64()
    );
    Ok(())
}
