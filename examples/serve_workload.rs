//! END-TO-END driver (EXPERIMENTS.md §E2E): serve a Poisson-arrival
//! workload of batched requests on the real tiny model and report
//! latency/throughput — the serving-paper validation required by
//! DESIGN.md. Compares the asynchronized-softmax engine (C1 on) against
//! the synchronized baseline (C1 off) on the same trace.
//!
//!     cargo run --release --example serve_workload [n_requests] [rate]

use std::time::{Duration, Instant};

use fdpp::api::{GenEvent, GenRequest, InferenceEngine};
use fdpp::config::EngineConfig;
use fdpp::engine::Engine;
use fdpp::runtime::Runtime;
use fdpp::workload::{generate, WorkloadSpec};

struct RunReport {
    label: String,
    wall: Duration,
    tokens: u64,
    finished: u64,
    p50_first: Duration,
    p95_first: Duration,
    p50_token: Duration,
    p95_token: Duration,
    recompute_rate: f64,
    kv_rebuilds: u64,
    mean_overhead: Duration,
}

fn run(label: &str, async_softmax: bool, n: usize, rate: f64) -> fdpp::Result<RunReport> {
    let spec = WorkloadSpec {
        rate,
        n_requests: n,
        prompt_len: (8, 48),
        max_new_tokens: (8, 32),
        seed: 42,
    };
    let trace = generate(&spec);
    let cfg = EngineConfig {
        // The sync baseline artifacts exist for buckets {1, 8}.
        decode_buckets: if async_softmax {
            vec![1, 2, 4, 8]
        } else {
            vec![1, 8]
        },
        async_softmax,
        ..EngineConfig::default()
    };
    let rt = Runtime::load("artifacts")?;
    let mut engine = Engine::new(rt, cfg)?;
    engine.warmup()?;

    let start = Instant::now();
    let mut pending = trace.iter().peekable();
    let mut receivers = Vec::new();
    // Replay the trace in virtual time: submit when arrival <= now, step
    // the engine in between (open-loop load generation).
    while pending.peek().is_some() || !engine.is_idle() {
        let now = start.elapsed().as_secs_f64();
        while let Some(req) = pending.peek() {
            if req.arrival_s <= now {
                let req = pending.next().unwrap();
                let gen = GenRequest::text(req.prompt.as_str())
                    .tenant(req.tenant.as_str())
                    .max_new_tokens(req.max_new_tokens);
                receivers.push(engine.submit(gen)?);
            } else {
                break;
            }
        }
        if !engine.is_idle() {
            engine.step()?;
        } else if pending.peek().is_some() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let wall = start.elapsed();

    // Drain streams (all finished).
    let mut total_events = 0u64;
    for h in &receivers {
        while let Ok(ev) = h.events.try_recv() {
            if matches!(ev, GenEvent::Token(_)) {
                total_events += 1;
            }
        }
    }
    assert_eq!(total_events, engine.metrics.tokens_generated);

    let m = &engine.metrics;
    Ok(RunReport {
        label: label.to_string(),
        wall,
        tokens: m.tokens_generated,
        finished: m.requests_finished,
        p50_first: m.first_token.percentile(0.5),
        p95_first: m.first_token.percentile(0.95),
        p50_token: m.per_token.percentile(0.5),
        p95_token: m.per_token.percentile(0.95),
        recompute_rate: m.recompute_rate(),
        kv_rebuilds: m.kv_rebuilds,
        mean_overhead: m.step_overhead.mean(),
    })
}

fn print_report(r: &RunReport) {
    println!("\n== {} ==", r.label);
    println!("requests finished     {}", r.finished);
    println!("tokens generated      {}", r.tokens);
    println!("wall time             {:.2?}", r.wall);
    println!(
        "throughput            {:.1} tok/s",
        r.tokens as f64 / r.wall.as_secs_f64()
    );
    println!("first-token p50/p95   {:.2?} / {:.2?}", r.p50_first, r.p95_first);
    println!("per-token  p50/p95    {:.2?} / {:.2?}", r.p50_token, r.p95_token);
    println!("recompute rate        {:.4}", r.recompute_rate);
    println!("kv rebuilds           {}", r.kv_rebuilds);
    println!("mean host overhead    {:.2?} per step", r.mean_overhead);
}

fn main() -> fdpp::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let rate: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);
    println!("serving {n} requests at ~{rate}/s on the tiny model (CPU PJRT)");

    let a = run("FlashDecoding++ (asynchronized softmax, C1 on)", true, n, rate)?;
    print_report(&a);
    let b = run("baseline (synchronized partial softmax, C1 off)", false, n, rate)?;
    print_report(&b);

    println!(
        "\nper-token p50 speedup from C1+buckets on this CPU testbed: {:.2}x",
        b.p50_token.as_secs_f64() / a.p50_token.as_secs_f64()
    );
    Ok(())
}
